import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (the XLA_FLAGS line above precedes every
other import; jax locks the device count on first init).  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
      --shape train_4k [--multi-pod] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all  # full sweep

Per cell this prints/saves: compiled memory_analysis (proves it fits),
cost_analysis FLOPs/bytes, and the collective-traffic table parsed from
the compiled HLO -- the inputs to EXPERIMENTS.md §Roofline.
"""  # noqa: E402

import argparse
import json
import re
import sys
import time
from typing import Any, Dict

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, skip_reason
from repro.launch import shard_rules, steps
from repro.launch.mesh import make_production_mesh
from repro.models import model
from repro.models.config import ModelConfig
from repro.models.sharding import use_mesh_hints
from repro.optim import adamw

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "s64": 8, "f64": 8}
_OP_RE = re.compile(r"= (.+?) (all-reduce|all-gather|reduce-scatter|"
                    r"all-to-all|collective-permute)(-start)?\(")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _group_size(line: str) -> int:
    m = _GROUP_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUP_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo: str) -> Dict[str, Any]:
    """Per-collective traffic from the compiled HLO.

    Compiled HLO prints operands by name only, so we take the *result*
    type(s) of each op and derive operand bytes:
      all-reduce / all-to-all / collective-permute: operand == result
      all-gather:     operand = result / group_size
      reduce-scatter: operand = result * group_size
    ``ring_wire_bytes`` estimates per-device link traffic with ring
    formulas: AR 2(g-1)/g * size, AG/RS (g-1)/g * full size, CP size.
    """
    out: Dict[str, Any] = {k: {"operand_bytes": 0, "result_bytes": 0,
                               "ring_wire_bytes": 0.0, "count": 0}
                           for k in COLLECTIVES}
    for line in hlo.splitlines():
        m = _OP_RE.search(line)
        if not m or "-done(" in line:
            continue
        rtype, kind = m.group(1), m.group(2)
        rbytes = 0
        for dm in _SHAPE_RE.finditer(rtype):
            dt, dims = dm.group(1), dm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            rbytes += n * _BYTES[dt]
        g = max(1, _group_size(line))
        if kind == "all-gather":
            obytes = rbytes // g
            wire = (g - 1) / g * rbytes
        elif kind == "reduce-scatter":
            obytes = rbytes * g
            wire = (g - 1) / g * obytes
        elif kind == "all-reduce":
            obytes = rbytes
            wire = 2 * (g - 1) / g * rbytes
        else:  # all-to-all, collective-permute
            obytes = rbytes
            wire = (g - 1) / g * rbytes if kind == "all-to-all" else rbytes
        rec = out[kind]
        rec["operand_bytes"] += obytes
        rec["result_bytes"] += rbytes
        rec["ring_wire_bytes"] += wire
        rec["count"] += 1
    out["total_wire_bytes"] = sum(v["ring_wire_bytes"] for v in out.values()
                                  if isinstance(v, dict))
    return out


def _scan_group(cfg: ModelConfig) -> int:
    """Layers per scan step (extrapolation unit)."""
    if cfg.family == "hybrid":
        return cfg.shared_attn_every
    if cfg.n_experts:
        return cfg.moe_layer_period
    return 1


def _cost_analysis_dict(ca) -> Dict[str, Any]:
    """Normalize ``compiled.cost_analysis()`` across jax versions:
    0.4.x returns a list with one dict per program, newer versions the
    dict itself."""
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca or {}


def _lower_and_cost(cfg, shape, mesh, opt_compress,
                    microbatches: int = 1) -> Dict[str, Any]:
    """Lower+compile one configuration; return raw per-device costs."""
    rec: Dict[str, Any] = {}
    pspecs = model.param_specs(cfg)
    psh = shard_rules.param_sharding(cfg, mesh, pspecs)
    t0 = time.time()
    with mesh, use_mesh_hints(mesh):
        if shape.kind == "train":
            opt_cfg = adamw.AdamWConfig(compress_grads=opt_compress)
            ospecs = adamw.state_specs(pspecs, opt_cfg)
            osh = shard_rules.opt_state_sharding(cfg, mesh, pspecs, ospecs)
            bspecs = steps.input_specs(cfg, shape)
            bsh = shard_rules.batch_sharding(mesh, bspecs)
            fn = steps.make_train_step(cfg, opt_cfg,
                                       microbatches=microbatches,
                                       grad_shardings=psh)
            jitted = jax.jit(
                fn,
                in_shardings=(psh, osh, bsh),
                out_shardings=(NamedSharding(mesh, P()), psh, osh),
                donate_argnums=(0, 1))
            lowered = jitted.lower(pspecs, ospecs, bspecs)
            tokens = shape.global_batch * shape.seq_len
            rec["model_flops"] = cfg.model_flops(tokens, training=True)
        elif shape.kind == "prefill":
            bspecs = steps.input_specs(cfg, shape)
            bsh = shard_rules.batch_sharding(mesh, bspecs)
            fn = steps.make_prefill_step(cfg)
            jitted = jax.jit(fn, in_shardings=(psh, bsh))
            lowered = jitted.lower(pspecs, bspecs)
            tokens = shape.global_batch * shape.seq_len
            rec["model_flops"] = cfg.model_flops(tokens, training=False)
        else:  # decode
            cspecs, ispec = steps.decode_extras(cfg, shape)
            csh = shard_rules.cache_sharding(cfg, mesh, cspecs)
            bspecs = steps.input_specs(cfg, shape)
            bsh = shard_rules.batch_sharding(mesh, bspecs)
            fn = steps.make_serve_step(cfg)
            jitted = jax.jit(
                fn,
                in_shardings=(psh, csh, bsh["tokens"],
                              NamedSharding(mesh, P())),
                donate_argnums=(1,))
            lowered = jitted.lower(pspecs, cspecs, bspecs["tokens"], ispec)
            rec["model_flops"] = cfg.model_flops(shape.global_batch,
                                                 training=False)
        rec["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

    ma = compiled.memory_analysis()
    rec["memory_per_device"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }
    ca = _cost_analysis_dict(compiled.cost_analysis())
    rec["cost_per_device"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
    }
    rec["collectives"] = collective_bytes(compiled.as_text())
    return rec


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opt_compress: bool = False,
             extrapolate: bool = True,
             microbatches: int = 1) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if reason:
        rec["skipped"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec["n_devices"] = int(mesh.devices.size)
    rec["microbatches"] = microbatches
    rec.update(_lower_and_cost(cfg, shape, mesh, opt_compress,
                               microbatches))

    if extrapolate:
        # XLA's cost analysis counts a while (scan) body ONCE regardless
        # of trip count.  Two-point extrapolation recovers exact totals:
        # compile at 1 and 2 scan groups, solve body = c2 - c1,
        # outside = c1 - body, total = outside + body * n_groups.
        g = _scan_group(cfg)
        trips_full = cfg.n_layers // g
        if trips_full > 2:
            c1 = _lower_and_cost(cfg.with_(n_layers=g, unroll=True),
                                 shape, mesh, opt_compress, microbatches)
            c2 = _lower_and_cost(cfg.with_(n_layers=2 * g, unroll=True),
                                 shape, mesh, opt_compress, microbatches)

            def extrap(f1: float, f2: float) -> float:
                body = f2 - f1
                outside = f1 - body
                return outside + body * trips_full

            rec["cost_per_device_scanned"] = {
                k: extrap(c1["cost_per_device"][k], c2["cost_per_device"][k])
                for k in ("flops", "bytes_accessed")
            }
            wire = {}
            for k in COLLECTIVES:
                wire[k] = extrap(c1["collectives"][k]["ring_wire_bytes"],
                                 c2["collectives"][k]["ring_wire_bytes"])
            wire["total"] = sum(wire.values())
            rec["collective_wire_bytes_scanned"] = wire
        else:
            rec["cost_per_device_scanned"] = dict(rec["cost_per_device"])
            wire = {k: rec["collectives"][k]["ring_wire_bytes"]
                    for k in COLLECTIVES}
            wire["total"] = sum(wire.values())
            rec["collective_wire_bytes_scanned"] = wire
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch, shape) on this mesh")
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in sorted(ARCHS):
            for s in sorted(SHAPES):
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    ok = True
    for arch, shape in cells:
        # scan-heavy families (ssm/hybrid) already fit without grad
        # accumulation, and their unrolled-microbatch extrapolation
        # compiles are prohibitively slow -- use mb=1 there
        mb = args.microbatches
        if get_config(arch).family in ("ssm", "hybrid"):
            mb = 1
        try:
            rec = run_cell(arch, shape, args.multi_pod,
                           microbatches=mb)
        except Exception as e:  # a failing cell is a bug in our system
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "error": f"{type(e).__name__}: {e}"}
            ok = False
        print(json.dumps(rec))
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
