"""Serving driver: batched prefill + decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
        --smoke --batch 4 --prompt-len 32 --gen 16

Demonstrates the full inference path (the ``decode_*`` dry-run shapes
lower exactly this ``serve_step``): prefill the prompt token-by-token
into the cache, then greedy-decode ``--gen`` new tokens.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.launch import steps as steps_mod
from repro.models import model


def serve(arch: str, smoke: bool, batch: int, prompt_len: int,
          gen: int, seed: int = 0):
    cfg = get_config(arch, smoke=smoke)
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    max_len = prompt_len + gen
    cache = model.init_cache(cfg, batch, max_len)
    step_fn = jax.jit(steps_mod.make_serve_step(cfg), donate_argnums=(1,))

    rng = np.random.RandomState(seed)
    if cfg.n_codebooks:
        prompt = rng.randint(0, cfg.vocab,
                             (batch, prompt_len, cfg.n_codebooks))
    else:
        prompt = rng.randint(0, cfg.vocab, (batch, prompt_len))
    prompt = jnp.asarray(prompt, jnp.int32)

    # prefill token-by-token through the decode path (a production
    # server would use the batched prefill_step; this exercises the
    # cache machinery end to end)
    t0 = time.time()
    nxt = None
    for i in range(prompt_len):
        tok = prompt[:, i:i + 1]
        nxt, cache = step_fn(params, cache, tok, jnp.int32(i))
    prefill_s = time.time() - t0

    out_tokens = []
    t0 = time.time()
    for i in range(prompt_len, prompt_len + gen):
        if cfg.n_codebooks:
            tok = nxt.reshape(batch, 1, cfg.n_codebooks)
        else:
            tok = nxt.reshape(batch, 1)
        nxt, cache = step_fn(params, cache, tok, jnp.int32(i))
        out_tokens.append(np.asarray(nxt))
    decode_s = time.time() - t0

    toks = np.stack(out_tokens, axis=1)
    print(f"prefill {prompt_len} tokens: {prefill_s:.2f}s; "
          f"decode {gen} tokens: {decode_s:.2f}s "
          f"({decode_s / max(gen,1) * 1e3:.0f} ms/token)")
    return toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    toks = serve(args.arch, args.smoke, args.batch, args.prompt_len,
                 args.gen)
    print("generated token block:", toks.shape)


if __name__ == "__main__":
    main()
