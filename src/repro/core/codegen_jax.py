"""Execution semantics for the PPL IR: lower patterns to pure JAX.

This is both the *oracle* (every transformation must preserve the value
computed here) and the CPU execution path used by benchmarks.  All loops
lower to ``jax.lax`` control flow so programs jit cleanly.

Index-map convention (see ir.py): every ``Access.index_map``,
``TileCopy.index_map`` and ``out_index_map`` receives the concatenated
index stack of all *enclosing* pattern domains, outermost first, ending
with the indices of the pattern that owns it.  Body ``fn``s receive the
same stack as their first argument.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import ir


def _key(src: ir.Source):
    """Binding key: TileCopies use their rewrite-stable uid."""
    return src.uid if isinstance(src, ir.TileCopy) else id(src)


def _unflatten(flat_idx, domain):
    """Flat loop index -> multi-index (row-major)."""
    idxs = []
    rem = flat_idx
    for extent in reversed(domain):
        idxs.append(rem % extent)
        rem = rem // extent
    return tuple(reversed(idxs))


def _squeeze(x):
    """Windows with singleton dims are squeezed; all-singleton -> scalar."""
    out = jnp.squeeze(x)
    return out


class Env:
    """Maps symbolic sources to concrete arrays during evaluation."""

    def __init__(self, inputs: Dict[str, Any]):
        self.inputs = inputs
        self.bindings: Dict[int, Any] = {}

    def resolve(self, src: ir.Source, idx_stack: Tuple) -> Any:
        if isinstance(src, ir.Tensor):
            if src.name not in self.inputs:
                raise KeyError(f"input tensor '{src.name}' not provided")
            return self.inputs[src.name]
        if _key(src) in self.bindings:
            return self.bindings[_key(src)]
        if isinstance(src, ir.Pattern):
            val = _execute(src, self, idx_stack)
            self.bindings[id(src)] = val
            return val
        if isinstance(src, ir.TileCopy):
            # lazy load: AffineMap index maps know their input arity, so we
            # can slice the correct stack prefix at the use site
            from .affine import AffineMap
            if isinstance(src.index_map, AffineMap):
                arr = self.resolve(src.src, idx_stack)
                starts = src.index_map(*idx_stack[:src.index_map.n_in])
                starts = tuple(jnp.asarray(s, jnp.int32) for s in starts)
                val = jax.lax.dynamic_slice(arr, starts, src.tile_shape)
                self.bindings[src.uid] = val
                return val
        raise KeyError(f"unbound source {src!r}")

    def bind(self, src: ir.Source, value: Any) -> None:
        self.bindings[_key(src)] = value


def _read_window(env: Env, access: ir.Access, idx_stack: Tuple) -> Any:
    arr = env.resolve(access.src, idx_stack)
    starts = access.index_map(*idx_stack)
    starts = tuple(jnp.asarray(s, jnp.int32) for s in starts)
    win = jax.lax.dynamic_slice(arr, starts, access.window)
    return _squeeze(win)


def _load_tiles(env: Env, p: ir.Pattern, idx_stack: Tuple) -> None:
    # tensor tile-loads first, then pattern-valued stages (which may read
    # the freshly loaded tiles) -- the metapipeline stage order
    loads = sorted(p.loads, key=lambda t: isinstance(t.src, ir.Pattern))
    for tc in loads:
        arr = env.resolve(tc.src, idx_stack)
        starts = tuple(jnp.asarray(s, jnp.int32)
                       for s in tc.index_map(*idx_stack))
        tile = jax.lax.dynamic_slice(arr, starts, tc.tile_shape)
        env.bind(tc, tile)


def _windows(env: Env, p: ir.Pattern, idx_stack: Tuple):
    return [_read_window(env, a, idx_stack) for a in p.accesses]


# --------------------------------------------------------------------------
# Per-pattern evaluators.  Each returns the pattern's realized value:
#   Map          -> array of shape domain + elem_shape
#   MultiFold    -> array of range_shape
#   FlatMap      -> (buffer, count)
#   GroupByFold  -> dense (num_keys,)+elem_shape accumulator
# --------------------------------------------------------------------------


def _execute_map(p: ir.Map, env: Env, outer_idx: Tuple) -> Any:
    n = p.trip_count

    def body(flat_i):
        idx = _unflatten(flat_i, p.domain)
        stack = outer_idx + idx
        sub = Env(env.inputs)
        sub.bindings = dict(env.bindings)
        _load_tiles(sub, p, stack)
        if p.inner is not None:
            val = _execute(p.inner, sub, stack)
            if isinstance(p.inner, ir.FlatMap):
                raise TypeError("FlatMap cannot nest inside Map (dynamic size)")
        else:
            val = p.fn(stack, *_windows(sub, p, stack))
        return jnp.asarray(val)

    vals = jax.vmap(body)(jnp.arange(n, dtype=jnp.int32))
    return vals.reshape(tuple(p.domain) + vals.shape[1:])


def _execute_multifold(p: ir.MultiFold, env: Env, outer_idx: Tuple,
                       flat_range: Optional[Tuple[int, int]] = None) -> Any:
    acc0 = jnp.asarray(p.init())
    assert acc0.shape == tuple(p.range_shape), (
        f"init shape {acc0.shape} != range {p.range_shape}")
    lo, hi = flat_range if flat_range is not None else (0, p.trip_count)
    upd_shape = tuple(p.update_shape)

    def body(flat_i, acc):
        idx = _unflatten(flat_i, p.domain)
        stack = outer_idx + idx
        sub = Env(env.inputs)
        sub.bindings = dict(env.bindings)
        _load_tiles(sub, p, stack)
        starts = tuple(jnp.asarray(s, jnp.int32)
                       for s in p.out_index_map(*stack))
        acc_slice = jax.lax.dynamic_slice(acc, starts, upd_shape)
        if p.inner is not None:
            partial = _execute(p.inner, sub, stack)
            partial = jnp.asarray(partial).reshape(upd_shape)
            if p.combine is None:  # write-once (tiled Map), paper's "(_)"
                new = partial
            else:
                new = p.combine(acc_slice, partial)
        else:
            new = p.fn(stack, acc_slice, *_windows(sub, p, stack))
        new = jnp.asarray(new, acc.dtype).reshape(upd_shape)
        return jax.lax.dynamic_update_slice(acc, new, starts)

    return jax.lax.fori_loop(lo, hi, body, acc0)


def _execute_multifold_parallel(p: ir.MultiFold, env: Env, outer_idx: Tuple,
                                num_partials: int) -> Any:
    """Fold ``num_partials`` contiguous chunks of the (row-major flattened)
    domain independently from ``init``, then merge with ``combine`` --
    validates that combine is associative with identity ``init`` (the
    parallel-partials path the FPGA reduction tree exploits)."""
    assert p.combine is not None, "write-once MultiFold has no combine"
    n = p.trip_count
    assert n % num_partials == 0
    chunk = n // num_partials
    partials = [
        _execute_multifold(p, env, outer_idx,
                           flat_range=(c * chunk, (c + 1) * chunk))
        for c in range(num_partials)
    ]
    out = partials[0]
    for q in partials[1:]:
        out = p.combine(out, q)
    return out


def _execute_flatmap(p: ir.FlatMap, env: Env, outer_idx: Tuple) -> Any:
    n = p.trip_count
    m = p.max_per_iter
    cap = n * m
    buf0 = jnp.zeros((cap,) + tuple(p.elem_shape),
                     dtype=jnp.result_type(p.dtype))

    def body(flat_i, carry):
        buf, count = carry
        idx = _unflatten(flat_i, p.domain)
        stack = outer_idx + idx
        sub = Env(env.inputs)
        sub.bindings = dict(env.bindings)
        _load_tiles(sub, p, stack)
        if p.inner is not None:
            vals, cnt = _execute(p.inner, sub, stack)
        else:
            vals, cnt = p.fn(stack, *_windows(sub, p, stack))
        vals = jnp.asarray(vals).reshape((-1,) + tuple(p.elem_shape))
        k = vals.shape[0]
        local = jnp.arange(k, dtype=jnp.int32)
        # invalid lanes scatter out of bounds and are dropped
        dest = jnp.where(local < cnt, count + local, cap)
        buf = buf.at[dest].set(vals, mode="drop")
        return (buf, count + jnp.asarray(cnt, jnp.int32))

    return jax.lax.fori_loop(0, n, body, (buf0, jnp.int32(0)))


def _execute_groupbyfold(p: ir.GroupByFold, env: Env, outer_idx: Tuple) -> Any:
    acc0 = jnp.asarray(p.init())
    assert acc0.shape == (p.num_keys,) + tuple(p.elem_shape)
    n = p.trip_count

    def body(flat_i, acc):
        idx = _unflatten(flat_i, p.domain)
        stack = outer_idx + idx
        sub = Env(env.inputs)
        sub.bindings = dict(env.bindings)
        _load_tiles(sub, p, stack)
        if p.inner is not None:
            # tiled form: inner yields a dense partial; combine keywise.
            # Correct because init is the identity of combine (required).
            partial = _execute(p.inner, sub, stack)
            return p.combine(acc, partial)
        key, val = p.fn(stack, *_windows(sub, p, stack))
        key = jnp.asarray(key, jnp.int32)
        cur = jax.lax.dynamic_slice(
            acc, (key,) + (0,) * len(p.elem_shape), (1,) + tuple(p.elem_shape))
        new = p.combine(cur[0], jnp.asarray(val, acc.dtype))
        new = jnp.asarray(new, acc.dtype).reshape((1,) + tuple(p.elem_shape))
        return jax.lax.dynamic_update_slice(
            acc, new, (key,) + (0,) * len(p.elem_shape))

    return jax.lax.fori_loop(0, n, body, acc0)


def _execute(p: ir.Pattern, env: Env, outer_idx: Tuple) -> Any:
    if isinstance(p, ir.Map):
        return _execute_map(p, env, outer_idx)
    if isinstance(p, ir.MultiFold):
        return _execute_multifold(p, env, outer_idx)
    if isinstance(p, ir.FlatMap):
        return _execute_flatmap(p, env, outer_idx)
    if isinstance(p, ir.GroupByFold):
        return _execute_groupbyfold(p, env, outer_idx)
    raise TypeError(f"unknown pattern {type(p)}")


def execute(p: ir.Pattern, inputs: Dict[str, Any], *,
            parallel_partials: Optional[int] = None) -> Any:
    """Evaluate pattern ``p`` with concrete ``inputs`` (name -> array)."""
    env = Env({k: jnp.asarray(v) for k, v in inputs.items()})
    if parallel_partials and isinstance(p, ir.MultiFold):
        return _execute_multifold_parallel(p, env, (), parallel_partials)
    return _execute(p, env, ())


def jit_execute(p: ir.Pattern):
    """A jitted closure over the pattern (inputs as kwargs)."""

    @jax.jit
    def run(**inputs):
        return execute(p, inputs)

    return run
