"""InternVL2-1B [arXiv:2404.16821; hf]: Qwen2-0.5B LM backbone; the
InternViT frontend is a STUB (input_specs() provides precomputed patch
embeddings prepended to the text sequence)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm", n_layers=24, d_model=896,
    n_heads=14, n_kv_heads=2, head_dim=64, d_ff=4864, vocab=151655, vocab_pad=9,
    activation="swiglu", qkv_bias=True, rope_theta=1e6,
    frontend_tokens=256)

SMOKE = CONFIG.with_(vocab_pad=0, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     head_dim=16, d_ff=128, vocab=256, frontend_tokens=8,
                     remat=False)
