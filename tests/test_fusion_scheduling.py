"""fusion.lift_tile_stages + scheduling/memory double-buffer invariants.

The metapipeline contracts the Pallas backend relies on (paper §5,
Fig. 6): every buffer crossing a stage boundary is double-buffered,
hoisted preloads are loop-invariant and single-buffered, and the
accumulator-dedup optimization keeps a single accumulator for tiled
MultiFolds.
"""
import pytest

import sys, os
sys.path.insert(0, os.path.dirname(__file__))
from test_core_transforms import mk_gemm, mk_kmeans, mk_sumrows

from repro.core import ir
from repro.core.affine import AffineMap
from repro.core.cost import traffic
from repro.core.fusion import fuse_pipeline_stages
from repro.core.memory import plan_memory
from repro.core.scheduling import build_schedule
from repro.core.strip_mine import tile


def _kmeans_tiled():
    scatter, *_ = mk_kmeans(48, 8, 5)
    return tile(scatter, {"scatter": (8,), "assign": (4,)})


# --------------------------------------------------- lift_tile_stages
def test_lift_creates_pattern_stage():
    t = _kmeans_tiled()
    stage_tcs = [tc for tc in t.loads if isinstance(tc.src, ir.Pattern)]
    assert len(stage_tcs) == 1
    (tc,) = stage_tcs
    assert tc.name == "assign_stage"
    assert tc.tile_shape == (8, 2)  # (b0, minDist pair)
    # the scatter tile loop reads the staged rows, not the raw pattern
    # (match by uid: later rewrites rebuild the TileCopy object)
    reads = [a for a in t.inner.accesses
             if isinstance(a.src, ir.TileCopy) and a.src.uid == tc.uid]
    assert reads, "consumer was not rewired to the lifted stage"


def test_lifted_stage_is_double_buffered_everywhere():
    t = _kmeans_tiled()
    mp = build_schedule(t)
    stage = [s for s in mp.stages if s.kind == "compute"]
    assert stage and all(s.double_buffered for s in stage)
    mem = plan_memory(t)
    stage_bufs = [b for b in mem.buffers
                  if b.name.startswith("assign_stage")]
    assert stage_bufs and all(b.double_buffered for b in stage_bufs)


# --------------------------------------------------- double-buffer rules
def test_every_stage_crossing_buffer_double_buffered():
    """Non-hoisted loads of a strided pattern are metapipeline-crossing
    buffers: double-buffered in both the schedule and the VMEM plan."""
    for prog in (_kmeans_tiled(),
                 tile(mk_sumrows(16, 32), {"sr": (4, 8)}),
                 tile(mk_gemm(16, 16, 32), {"gemm": (8, 8),
                                            "kfold": (16,)})):
        mp = build_schedule(prog)
        assert all(s.double_buffered for s in mp.stages
                   if s.kind in ("load", "compute", "body"))
        mem = plan_memory(prog)
        hoisted = {tc.name for q in ir.walk(prog) for tc in q.loads
                   if tc.hoisted}
        for q in ir.walk(prog):
            if not q.strided:
                continue
            for tc in q.loads:
                bufs = [b for b in mem.buffers
                        if b.name.startswith(tc.name + "#")]
                want = not tc.hoisted
                assert bufs and all(
                    b.double_buffered == want for b in bufs), (
                    tc.name, hoisted)


def test_preloads_are_loop_invariant():
    """Hoisted loads sit in Pipe 0: constant index map (no dependence on
    any loop index), loaded exactly once, never double-buffered.  The
    kmeans pipeline is a fan-out DAG now, so check the terminal tree
    that carries the assign stage's centroids preload."""
    from repro.patterns.analytics import kmeans_pipeline
    pipe, _, _ = kmeans_pipeline()
    from repro.core.pipeline import fuse_dag
    fdag = fuse_dag(pipe, 128)
    fused = fdag.terminals[0][1]
    hoisted = [tc for q in ir.walk(fused) for tc in q.loads if tc.hoisted]
    assert any("centroids" in tc.name for tc in hoisted)
    for tc in hoisted:
        amap = tc.index_map
        assert isinstance(amap, AffineMap)
        assert not amap.dependent_dims()  # loop-invariant
    mp = build_schedule(fused)
    assert {s.name for s in mp.preloads} >= {tc.name for tc in hoisted
                                             if tc.words}
    assert all(not s.double_buffered for s in mp.preloads)
    # loaded once: traffic charges the tensor a single tile
    tr = traffic(fused)
    cents = [tc for tc in hoisted if "centroids" in tc.name][0]
    assert tr.reads["centroids"] == cents.words


# --------------------------------------------------- accumulator dedup
def test_accumulator_dedup_single_accumulator():
    """A MultiFold tiled into MultiFold-of-MultiFold keeps ONE
    accumulator: the schedule flags the dedup and the memory plan holds
    no intermediate partial buffer (only tile-copy loads)."""
    t = tile(mk_sumrows(16, 32), {"sr": (4, 8)})
    mp = build_schedule(t)
    assert mp.fused_accumulator
    assert sum(s.kind == "body" for s in mp.stages) == 1
    mem = plan_memory(t)
    # all VMEM buffers are tile copies of the input -- no partial acc
    assert all(b.name.startswith("x_tile") for b in mem.buffers), \
        [b.name for b in mem.buffers]


def test_accumulator_forwarding_flagged_when_acc_too_big():
    t = tile(mk_sumrows(16, 32), {"sr": (4, 8)})
    mp = build_schedule(t, vmem_budget_words=4)  # acc (16,) > 4 words
    assert mp.accumulator_forwarding


# ------------------------------------------- cross-pattern stage lifting
def test_fuse_pipeline_stages_rejects_non_row_access():
    import jax.numpy as jnp
    x = ir.Tensor("x", (64,))
    prod = ir.Map(domain=(64,), reads=(ir.elem(x),),
                  fn=lambda s, e: e, name="p")
    # consumer reads the intermediate *reversed*: not fusable in place
    rev = ir.MultiFold(
        domain=(64,), range_shape=(), init=lambda: jnp.zeros(()),
        reads=(ir.Access(ir.Tensor("p", (64,)),
                         lambda i: (63 - i,), (1,)),),
        out_index_map=lambda i: (), update_shape=(),
        fn=lambda s, acc, v: acc + v, combine=lambda a, b: a + b,
        name="c")
    with pytest.raises(NotImplementedError, match="row access"):
        fuse_pipeline_stages((prod, rev), 16)


def test_fuse_pipeline_stages_requires_shared_domain():
    import jax.numpy as jnp
    x = ir.Tensor("x", (64,))
    prod = ir.Map(domain=(32,), reads=(), fn=lambda s: 1.0, name="p")
    cons = ir.MultiFold(
        domain=(64,), range_shape=(), init=lambda: jnp.zeros(()),
        reads=(ir.elem(x),), out_index_map=lambda i: (),
        update_shape=(), fn=lambda s, acc, v: acc + v,
        combine=lambda a, b: a + b, name="c")
    with pytest.raises(ValueError, match="share the streaming domain"):
        fuse_pipeline_stages((prod, cons), 16)
