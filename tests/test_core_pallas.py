"""Pallas backend vs jnp oracle for the tiled canonical forms."""
import numpy as np

from repro.core.codegen_jax import execute
from repro.core.codegen_pallas import lower
from repro.core.strip_mine import tile
from repro.core.scheduling import build_schedule
from repro.core.memory import plan_memory

import sys, os
sys.path.insert(0, os.path.dirname(__file__))
from test_core_transforms import mk_filter, mk_gemm, mk_hist, mk_map_2x, _rng


def test_pallas_tiled_map():
    p = tile(mk_map_2x(64), {"m": (16,)})
    x = _rng(64)
    out = lower(p)(x=x)
    np.testing.assert_allclose(out, 2 * x, rtol=1e-6)


def test_pallas_tiled_gemm():
    g = mk_gemm(16, 24, 32)
    t = tile(g, {"gemm": (8, 12), "kfold": (16,)})
    x, y = _rng(16, 32), _rng(32, 24)
    out = lower(t)(x=x, y=y)
    np.testing.assert_allclose(out, x @ y, rtol=1e-4, atol=1e-4)


def test_pallas_tiled_groupby():
    p = tile(mk_hist(64, 8), {"h": (16,)})
    x = np.abs(_rng(64)) * 4
    out = lower(p)(x=x)
    ref = execute(p, {"x": x})
    np.testing.assert_allclose(out, ref, rtol=1e-6)


def test_pallas_tiled_flatmap():
    p = tile(mk_filter(64), {"f": (16,)})
    x = _rng(64)
    buf, cnt = lower(p)(x=x)
    ref = x[x > 0]
    assert int(cnt) == len(ref)
    np.testing.assert_allclose(np.asarray(buf)[:len(ref)], ref, rtol=1e-6)


def test_schedule_and_memory_kmeans():
    from test_core_transforms import mk_kmeans
    scatter, *_ = mk_kmeans(24, 6, 5)
    t = tile(scatter, {"scatter": (8,), "assign": (3,)})
    mp = build_schedule(t)
    assert mp is not None
    kinds = [s.kind for s in mp.stages]
    # load points tile, compute assignment stage, scatter body, store
    assert "load" in kinds and "compute" in kinds and "body" in kinds
    # all cross-stage buffers double buffered
    assert all(s.double_buffered for s in mp.stages
               if s.kind in ("load", "compute", "body"))
    plan = plan_memory(t)
    assert plan.fits
    kinds = {b.kind for b in plan.buffers}
    assert "double_buffer" in kinds and "cam_dense" in kinds
