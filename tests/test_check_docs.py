"""The docs link checker behind CI's docs-check step."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))
import check_docs  # noqa: E402


def _write(root, rel, body):
    path = os.path.join(root, rel)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(body)
    return path


def test_repo_docs_have_no_broken_links():
    root = os.path.join(os.path.dirname(__file__), "..")
    files = check_docs.markdown_files(os.path.realpath(root), [])
    assert files  # README + docs/ must exist
    broken, _ = check_docs.check(os.path.realpath(root), files)
    assert broken == []


def test_broken_relative_link_fails(tmp_path):
    root = str(tmp_path)
    _write(root, "README.md", "[docs](docs/missing.md)\n")
    assert check_docs.main(["--root", root]) == 1


def test_good_links_and_anchors_pass(tmp_path):
    root = str(tmp_path)
    _write(root, "docs/a.md", "# Top Section\nsee [b](b.md#other)\n")
    _write(root, "docs/b.md", "# Other\nback to [a](a.md#top-section)\n")
    _write(root, "README.md",
           "[a](docs/a.md)\n[self](#intro)\n# Intro\n")
    assert check_docs.main(["--root", root]) == 0


def test_missing_anchor_in_target_fails(tmp_path):
    root = str(tmp_path)
    _write(root, "docs/a.md", "# Only Heading\n")
    _write(root, "README.md", "[a](docs/a.md#nope)\n")
    assert check_docs.main(["--root", root]) == 1


def test_external_and_escaping_links_do_not_fail(tmp_path):
    root = str(tmp_path)
    _write(root, "README.md",
           "[x](https://example.com/page)\n"
           "[badge](../../actions/workflows/ci.yml)\n")
    assert check_docs.main(["--root", root]) == 0


def test_code_fences_are_ignored(tmp_path):
    root = str(tmp_path)
    _write(root, "README.md",
           "```md\n[broken](not/a/file.md)\n```\n")
    assert check_docs.main(["--root", root]) == 0
