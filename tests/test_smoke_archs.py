"""Per-architecture smoke tests: reduced config, one forward/train step
on CPU, asserting output shapes and no NaNs; plus one decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import model
from repro.models.config import ModelConfig

BATCH, SEQ = 2, 32


def make_batch(cfg: ModelConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.n_codebooks:
        tokens = jax.random.randint(k1, (BATCH, SEQ, cfg.n_codebooks),
                                    0, cfg.vocab)
    else:
        tokens = jax.random.randint(k1, (BATCH, SEQ), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            k3, (BATCH, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_no_nans(arch):
    cfg = get_config(arch, smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits = jax.jit(lambda p, b: model.forward(p, cfg, b))(params, batch)
    s = SEQ + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    if cfg.n_codebooks:
        assert logits.shape == (BATCH, s, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (BATCH, s, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_no_nans(arch):
    cfg = get_config(arch, smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    @jax.jit
    def step(p, b):
        l, g = jax.value_and_grad(lambda pp: model.loss(pp, cfg, b))(p)
        return l, g

    l, g = step(params, batch)
    assert np.isfinite(float(l))
    flat = jax.tree.leaves(g)
    assert all(bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
               for x in flat)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    cache = model.init_cache(cfg, BATCH, max_len=64)
    if cfg.n_codebooks:
        tok = jnp.zeros((BATCH, 1, cfg.n_codebooks), jnp.int32)
    else:
        tok = jnp.zeros((BATCH, 1), jnp.int32)
    step = jax.jit(lambda p, c, t, i: model.decode_step(p, cfg, c, t, i))
    logits, cache = step(params, cache, tok, jnp.int32(0))
    logits2, cache = step(params, cache, tok, jnp.int32(1))
    assert not bool(jnp.any(jnp.isnan(logits2.astype(jnp.float32))))


def test_param_counts_match_published():
    """Analytic param counts should land near the published sizes."""
    expect = {
        "starcoder2-15b": (15e9, 0.25),
        "nemotron-4-15b": (15e9, 0.30),   # large embed share
        "granite-3-2b": (2.5e9, 0.35),
        "qwen2-72b": (72e9, 0.15),
        "mamba2-370m": (370e6, 0.25),
        "mixtral-8x22b": (141e9, 0.15),
        "llama4-maverick-400b-a17b": (400e9, 0.20),
        "zamba2-2.7b": (2.7e9, 0.40),
        "musicgen-medium": (1.5e9, 0.5),
        "internvl2-1b": (0.9e9, 0.5),     # LM backbone only
    }
    for arch, (want, tol) in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < tol, (arch, got, want)
