"""Assigned input-shape set (applies to every architecture).

``train_*`` lowers train_step; ``prefill_*`` lowers a forward pass;
``decode_*`` / ``long_*`` lower serve_step (one new token against a KV
cache of ``seq_len``).  ``long_500k`` requires sub-quadratic attention:
it runs for SSM / hybrid / sliding-window archs and is skipped (with a
note) for pure full-attention archs -- see DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def sub_quadratic(cfg: ModelConfig) -> bool:
    return cfg.family in ("ssm", "hybrid") or cfg.sliding_window is not None


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and not sub_quadratic(cfg):
        return ("full-attention arch: 512k dense-KV decode is "
                "quadratic/unbounded -- skipped per DESIGN.md §5")
    return None
