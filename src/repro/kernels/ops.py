"""Public jit'd wrappers for the Pallas kernels.

Every op takes ``use_pallas``: True -> the Pallas kernel (interpret mode
on CPU, compiled on TPU); False -> the jnp oracle (used by the 512-device
dry-run, where interpret-mode kernels would be pure overhead).  Both
paths are numerically validated against each other in tests/.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import filter_reduce as _fr
from . import flash_attention as _fa
from . import groupby_fold as _gbf
from . import matmul as _mm
from . import ref
from . import ssd_scan as _ssd


@functools.partial(jax.jit, static_argnames=("use_pallas", "block_m",
                                             "block_n", "block_k"))
def matmul(x, y, *, use_pallas: bool = True, block_m: int = 128,
           block_n: int = 128, block_k: int = 128):
    if use_pallas:
        return _mm.matmul(x, y, block_m=block_m, block_n=block_n,
                          block_k=block_k)
    return ref.matmul(x, y).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "use_pallas", "block_q",
                                             "block_k"))
def attention(q, k, v, *, causal: bool = True,
              window: Optional[int] = None, use_pallas: bool = True,
              block_q: int = 128, block_k: int = 128):
    if use_pallas:
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k)
    return ref.attention(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def ssd(x, dt, A, B, C, *, chunk: int = 128, use_pallas: bool = True):
    if use_pallas:
        return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk)
    return ref.ssd_scan(x, dt, A, B, C)


@functools.partial(jax.jit, static_argnames=("num_keys", "use_pallas",
                                             "block_t"))
def groupby(keys, values, num_keys: int, *, use_pallas: bool = True,
            block_t: int = 256):
    if use_pallas:
        return _gbf.groupby_fold(keys, values, num_keys, block_t=block_t)
    return ref.groupby_fold(keys, values, num_keys)


@functools.partial(jax.jit, static_argnames=("use_pallas", "block_t"))
def filter_sum(x, weight, lo, hi, *, use_pallas: bool = True,
               block_t: int = 1024):
    if use_pallas:
        return _fr.filter_reduce(x, weight, lo, hi, block_t=block_t)
    return ref.filter_reduce(x, jnp.float32(lo), jnp.float32(hi), weight)
