"""The measured-timing subsystem (repro.core.measure) and the hybrid
analytic->measured DSE paths that consume it.

Covers the ISSUE-5 acceptance surface: warmup exclusion (compile time
never pollutes steady-state medians), timing-DB round-trip and
memoization (a cache-warm exploration does zero lowering and zero
execution), the interpret-mode fallback on CPU, and the measured
``explore``/``explore_pipeline`` modes.
"""
import json
import time

import numpy as np
import pytest

from repro.core import dse, ir, measure

jax = pytest.importorskip("jax")


# --------------------------------------------------------------- measure()
def test_measure_excludes_warmup_and_reports_median():
    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        if calls["n"] == 1:          # "compile": must not be timed
            time.sleep(0.05)
        return calls["n"]

    m = measure.measure(fn, warmup=1, repeat=3)
    assert calls["n"] == 4           # warmup ran, just untimed
    assert m.median_s < 0.05         # the sleep was excluded
    assert m.repeat == 3 and m.warmup == 1
    assert m.min_s <= m.median_s <= m.max_s
    assert not m.cached


def test_measure_validates_arguments():
    with pytest.raises(ValueError):
        measure.measure(lambda: None, repeat=0)
    with pytest.raises(ValueError):
        measure.measure(lambda: None, warmup=-1)


def test_measurement_records_device_and_interpret_mode():
    m = measure.measure(lambda: 1, warmup=0, repeat=1)
    assert m.device == measure.device_kind()
    assert m.interpret == measure.interpret_mode()


# --------------------------------------------------------------- TimingDB
def test_timing_db_roundtrip(tmp_path):
    path = str(tmp_path / "db.json")
    db = measure.TimingDB(path)
    m = measure.measure(lambda: 1, warmup=0, repeat=2)
    db.put("k1", m)

    fresh = measure.TimingDB(path)   # new instance, same file
    got = fresh.get("k1")
    assert got is not None and got.cached
    assert got.median_s == m.median_s
    assert got.repeat == m.repeat
    assert fresh.get("other") is None


def test_timing_db_keys_are_device_and_interpret_scoped():
    k = measure.TimingDB.full_key("abc")
    assert measure.device_kind() in k
    assert f"interp={int(measure.interpret_mode())}" in k
    # a compiled-TPU timing can never alias an interpreted-CPU one
    assert measure.TimingDB.full_key("abc", device="tpu-v5e",
                                     interpret=False) != k


def test_timing_db_corrupt_file_reads_as_empty(tmp_path):
    path = tmp_path / "db.json"
    path.write_text("{not json")
    db = measure.TimingDB(str(path))
    assert db.get("k") is None
    db.put("k", measure.measure(lambda: 1, warmup=0, repeat=1))
    assert measure.TimingDB(str(path)).get("k") is not None


def test_timed_memoizes_and_skips_lowering_on_hit(tmp_path):
    db = measure.TimingDB(str(tmp_path / "db.json"))
    built = {"n": 0}

    def make_fn():
        built["n"] += 1
        return lambda: 1

    m1 = measure.timed("key", make_fn, db=db, warmup=0, repeat=1)
    assert built["n"] == 1 and not m1.cached
    m2 = measure.timed("key", make_fn, db=db, warmup=0, repeat=1)
    assert built["n"] == 1           # DB hit: thunk never invoked
    assert m2.cached and m2.median_s == m1.median_s


# --------------------------------------------------------- synth inputs
def test_synth_inputs_deterministic_and_typed():
    tensors = (ir.Tensor("x", (8, 4)), ir.Tensor("k", (8,), "int32"))
    a = measure.synth_inputs(tensors)
    b = measure.synth_inputs(tensors)
    assert a["x"].shape == (8, 4) and a["x"].dtype == np.float32
    assert a["k"].dtype == np.int32
    assert int(a["k"].min()) >= 0    # keys stay one-hot-safe
    np.testing.assert_array_equal(np.asarray(a["x"]), np.asarray(b["x"]))
    np.testing.assert_array_equal(np.asarray(a["k"]), np.asarray(b["k"]))


# ------------------------------------------------------------- spearman
def test_spearman():
    assert measure.spearman([1, 2, 3], [10, 20, 30]) == 1.0
    assert measure.spearman([1, 2, 3], [30, 20, 10]) == -1.0
    assert measure.spearman([1.0, 1.0], [1.0, 1.0]) == 1.0   # both tied
    assert measure.spearman([1.0, 1.0], [1.0, 2.0]) == 0.0
    assert measure.spearman([5], [7]) == 1.0
    assert abs(measure.spearman([1, 2, 3, 4], [1, 3, 2, 4]) - 0.8) < 1e-9
    with pytest.raises(ValueError):
        measure.spearman([1], [1, 2])


# ------------------------------------------- lower_for_timing (interpret)
def test_lower_for_timing_runs_on_cpu_interpret():
    """The CPU container times interpret-mode kernels: the fallback the
    ISSUE requires.  filter_reduce's proxy has no Pallas template for
    its tiled fold, so it must route to the jitted oracle."""
    from repro.core.codegen_pallas import lower_for_timing

    p = dse.filter_reduce_program(512)
    fn, how = lower_for_timing(p, {"fr": (128,)})
    assert how in ("pallas", "oracle")
    out = jax.block_until_ready(fn())
    assert np.isfinite(float(np.asarray(out)))
    m = measure.measure(fn, warmup=1, repeat=2)
    assert m.median_s > 0


# ----------------------------------------------------- hybrid explore()
def test_explore_measured_returns_timed_plan(tmp_path):
    p = dse.filter_reduce_program(1024)
    plan = dse.explore(p, cache=str(tmp_path / "cache.json"),
                       timing_db=str(tmp_path / "db.json"),
                       measure="top_k", top_k=2, warmup=1, repeat=1)
    assert plan.measured
    assert plan.timed >= 1
    assert plan.measured_seconds > 0
    assert "fr" in plan.sizes


def test_explore_measured_second_call_zero_lowering(tmp_path, monkeypatch):
    from repro.core import codegen_pallas

    p = dse.filter_reduce_program(1024)
    kw = dict(cache=str(tmp_path / "cache.json"),
              timing_db=str(tmp_path / "db.json"),
              measure="top_k", top_k=2, warmup=1, repeat=1)
    plan1 = dse.explore(p, **kw)

    def boom(*a, **k):
        raise AssertionError("second exploration must not lower")

    monkeypatch.setattr(codegen_pallas, "lower_for_timing", boom)
    plan2 = dse.explore(p, **kw)
    assert plan2.cached
    assert plan2.sizes == plan1.sizes
    assert plan2.measured and plan2.measured_seconds > 0


def test_explore_measured_updates_calibration_profile(tmp_path):
    from repro.core import calibrate

    assert calibrate.load_profile() is None
    assert calibrate.active_profile_hash() == calibrate.UNCALIBRATED
    p = dse.filter_reduce_program(1024)
    dse.explore(p, cache=False, timing_db=str(tmp_path / "db.json"),
                measure="top_k", top_k=2, warmup=1, repeat=1)
    prof = calibrate.load_profile()
    assert prof is not None and prof.n_samples >= 1
    assert calibrate.active_profile_hash() == prof.hash


def test_recalibration_invalidates_tuning_cache(tmp_path):
    """Satellite: the cache key carries device kind + profile hash, so
    a tuned plan goes stale the moment the calibration changes."""
    from repro.core import calibrate

    p = dse.filter_reduce_program(2048)
    cache = str(tmp_path / "cache.json")
    dse.explore(p, cache=cache)
    assert dse.explore(p, cache=cache).cached

    calibrate.observe([calibrate.Sample(
        workload="w", kind="MultiFold", stream_bytes=1e6, steps=4,
        measured_s=1e-3)])
    plan = dse.explore(p, cache=cache)   # new profile hash -> new key
    assert not plan.cached
    assert dse.explore(p, cache=cache).cached   # re-tuned and re-cached


def test_pattern_key_scoped_by_device_and_profile():
    p = dse.filter_reduce_program(256)
    base = dse.pattern_key(p, device="cpu", profile_hash="uncalibrated")
    assert dse.pattern_key(p, device="tpu-v5e",
                           profile_hash="uncalibrated") != base
    assert dse.pattern_key(p, device="cpu", profile_hash="abc123") != base


def test_repro_measure_env_opt_in(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_MEASURE", "top_k")
    p = dse.filter_reduce_program(1024)
    plan = dse.explore(p, cache=False,
                       timing_db=str(tmp_path / "db.json"),
                       top_k=1, warmup=1, repeat=1)
    assert plan.measured
    monkeypatch.setenv("REPRO_MEASURE", "bogus")
    with pytest.raises(ValueError):
        dse.explore(p, cache=False)


# -------------------------------------------- hybrid explore_pipeline()
@pytest.mark.slow
def test_explore_pipeline_measured(tmp_path, monkeypatch):
    from repro.core import codegen_pallas

    pipe = dse.filter_fold_pipeline(1024)
    kw = dict(cache=str(tmp_path / "cache.json"),
              timing_db=str(tmp_path / "db.json"),
              measure="top_k", top_k=2, warmup=1, repeat=1)
    plan = dse.explore_pipeline(pipe, **kw)
    assert plan.measured and plan.timed >= 1
    assert plan.measured_seconds > 0
    assert plan.fused

    def boom(*a, **k):
        raise AssertionError("second exploration must not lower")

    monkeypatch.setattr(codegen_pallas, "lower_pipeline_for_timing", boom)
    plan2 = dse.explore_pipeline(pipe, **kw)
    assert plan2.cached and plan2.block == plan.block


def test_measured_shortlist_records(tmp_path):
    ts = dse.measured_shortlist(
        dse.filter_reduce_program(1024), top_k=2,
        timing_db=str(tmp_path / "db.json"), warmup=1, repeat=1)
    assert 1 <= len(ts) <= 2
    for t in ts:
        assert t.analytic_seconds > 0
        assert t.calibrated_seconds > 0
        assert t.measurement.median_s > 0
        assert t.steps >= 1
        assert t.lowering in ("pallas", "oracle", "cached")


def test_tile_plan_measured_fields_roundtrip(tmp_path):
    plan = dse.TilePlan(sizes={"a": (8,)}, traffic_words=10,
                        vmem_bytes=100, modeled_seconds=1e-6,
                        measured=True, measured_seconds=2e-6, timed=3)
    got = dse.TilePlan.from_json(json.loads(json.dumps(plan.to_json())))
    assert got.measured and got.measured_seconds == 2e-6 and got.timed == 3


def test_grid_steps():
    p = dse.gemm_program(256, 128, 512)
    assert dse.grid_steps(p, {"gemm": (128, 64), "gemm_k": (128,)}) \
        == 2 * 2 * 4
    assert dse.grid_steps(p, {"gemm": (256, 128), "gemm_k": (512,)}) == 1
