"""Fault tolerance for the tuning runtime (quarantine, deadlines,
certification, crash-safe stores, fault injection).

The measured-refinement loop (``dse.explore(measure="top_k")`` ->
``codegen_pallas.lower_for_timing`` -> ``measure.measure`` ->
``calibrate.observe``) runs arbitrary candidate kernels through a real
compiler and a real backend; any of those steps can raise, hang, or --
worst -- silently produce wrong numbers that would then be cached and
served indefinitely.  "Best-Effort FPGA Programming" (Cong et al.)
frames the requirement: a measured loop is only worth having if a
failing candidate costs one candidate, not the exploration.  This
module is the layer that enforces it:

  * **Failure taxonomy + structured events** -- every fallback,
    quarantine, retry and store rebuild is a ``FailureEvent`` recorded
    in the process-wide ``LOG`` (and mirrored to ``logging``), so
    degradation is observable instead of swallowed.  The taxonomy
    splits *expected* candidate failures (``EXPECTED_ERRORS``:
    lowering/type/backend errors, deadlines, injected faults) from
    real bugs (``AttributeError``, ``NameError``, assertion failures),
    which always propagate.
  * **Candidate quarantine** -- a candidate whose lowering, timing or
    certification fails is recorded in the DSE tuning cache (keyed per
    device + interpret mode) and never re-attempted; the shortlist
    simply continues with the next candidate.
  * **Deadlines + retry/backoff** (``call_guarded`` /
    ``run_with_deadline``) -- per-candidate lower+time work runs under
    a wall-clock deadline in a worker thread; a hung compile degrades
    to ``DeadlineExceeded`` ("candidate timed out, quarantined")
    instead of blocking ``explore`` forever.  Transient failures are
    retried with exponential backoff; deterministic ones are not.
  * **Plan certification** (``certify_tile_plan`` /
    ``certify_pipeline_plan``) -- before a measured winner is promoted
    into ``REPRO_DSE_CACHE``, its lowered kernel is numerically
    validated against the ``codegen_jax`` oracle with dtype-aware
    tolerances; a wrong winner is quarantined and the next candidate
    promoted.
  * **Crash-safe stores** (``load_store`` / ``save_store`` /
    ``locked_update``) -- checksummed, versioned, lock-protected
    atomic JSON persistence shared by the DSE cache, the timing DB and
    the calibration profile.  A truncated or corrupt file is moved to
    ``<path>.corrupt`` (named in a warning) and the store rebuilds
    fresh; a version-skewed store is ignored, never misread.
  * **Deterministic fault injection** (``REPRO_FAULTS=lower:0.5,
    time:0.3``) -- ``inject(site)`` hooks at every layer raise
    ``InjectedFault`` on a counter-hashed deterministic schedule, so
    tests and the CI chaos smoke can prove each layer degrades instead
    of dying.  Same env + same call sequence -> same faults.

Env knobs (all read per ``default_policy()`` call, so tests can
monkeypatch them): ``REPRO_FAULTS``, ``REPRO_TIMEOUT_S`` (per-candidate
deadline, default 120; ``0`` disables), ``REPRO_RETRIES`` (default 1),
``REPRO_BACKOFF_S`` (default 0.05), ``REPRO_CERTIFY`` (``0`` skips
winner certification).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import logging
import os
import queue
import tempfile
import threading
import time
import warnings
from typing import Callable, Dict, List, Optional, Tuple

from . import telemetry

logger = logging.getLogger("repro.resilience")

# Persistent-store format revision.  Bumped when the on-disk envelope
# (not the payload semantics -- those carry their own versions, e.g.
# dse.MODEL_VERSION inside every cache key) changes incompatibly.
STORE_VERSION = 1

# --------------------------------------------------------------------------
# Failure taxonomy
# --------------------------------------------------------------------------


class InjectedFault(RuntimeError):
    """A deliberate failure raised by the fault-injection harness."""

    def __init__(self, site: str, detail: str = ""):
        super().__init__(f"injected fault at {site}"
                         + (f": {detail}" if detail else ""))
        self.site = site


class DeadlineExceeded(TimeoutError):
    """A guarded call outlived its per-candidate deadline."""


class CandidateFailure(Exception):
    """A classified, *expected* candidate failure: the candidate is
    quarantined and exploration continues.  ``kind`` is the taxonomy
    bucket, ``detail`` the human-readable reason."""

    def __init__(self, kind: str, detail: str):
        super().__init__(f"{kind}: {detail}")
        self.kind = kind
        self.detail = detail


# Exceptions a lowering/compile/timing boundary is *allowed* to throw:
# template mismatches and unsupported shapes (ValueError/TypeError/
# KeyError/IndexError/NotImplementedError), backend and XLA runtime
# errors (RuntimeError covers jaxlib's XlaRuntimeError), numeric traps,
# I/O, deadlines and injected faults.  Everything else -- Attribute/
# Name/ImportError, assertion failures -- is a real bug in this repo
# and propagates instead of being quarantined.
EXPECTED_ERRORS: Tuple[type, ...] = (
    ValueError, TypeError, KeyError, IndexError, NotImplementedError,
    ArithmeticError, RuntimeError, OSError, MemoryError,
    DeadlineExceeded,
)

# Failure kinds a retry can plausibly fix (resource blips).  A
# deadline is NOT retryable: the work already burned a full timeout,
# and a deterministic hang would just burn another.
RETRYABLE_KINDS = frozenset({"transient"})


def classify(exc: BaseException) -> str:
    """Map an exception from a guarded boundary onto the taxonomy."""
    if isinstance(exc, InjectedFault):
        return f"injected:{exc.site}"
    if isinstance(exc, DeadlineExceeded):
        return "timeout"
    if isinstance(exc, NotImplementedError):
        return "lower-unsupported"
    if isinstance(exc, (ValueError, TypeError, KeyError, IndexError)):
        return "lower-error"
    if isinstance(exc, ArithmeticError):
        return "numeric-error"
    if isinstance(exc, (OSError, MemoryError)):
        return "transient"
    if isinstance(exc, RuntimeError):
        return "compile-error"
    return f"unexpected:{type(exc).__name__}"


# --------------------------------------------------------------------------
# Structured events
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """One structured degradation event.

    ``stage``: where in the runtime ("lower", "time", "certify",
    "store", "tile"); ``kind``: taxonomy bucket from ``classify``;
    ``key``: the candidate / file identity; ``action``: what the
    runtime did about it ("quarantined", "skipped", "retried",
    "fallback", "rebuilt"); ``detail``: human-readable reason.
    """

    stage: str
    kind: str
    key: str
    action: str
    detail: str = ""

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


class EventLog:
    """Process-wide append-only log of degradation events.

    ``counts()`` aggregates by action -- the numbers
    ``benchmarks/run.py`` emits into the BENCH json and the CI chaos
    smoke asserts are nonzero under injected faults.  Thread-safe (the
    deadline worker threads record through it).

    Storage-wise this is a facade over the single structured event
    stream in ``core.telemetry`` (each ``EventLog`` instance owns a
    stream name; the process-wide ``LOG`` uses ``"resilience"``), so
    degradation events land in the same export as spans and the
    recovery log -- the public ``record`` / ``events`` / ``counts`` /
    ``reset`` API is unchanged.
    """

    _ids = itertools.count()

    def __init__(self):
        i = next(EventLog._ids)
        self.stream = "resilience" if i == 0 else f"resilience.{i}"
        self._once: set = set()
        self._lock = threading.Lock()

    def record(self, event: FailureEvent) -> None:
        telemetry.emit(self.stream, event.kind, stage=event.stage,
                       key=event.key, action=event.action,
                       detail=event.detail)
        logger.warning("resilience[%s/%s] %s: %s (%s)", event.stage,
                       event.kind, event.action, event.key, event.detail)

    def record_once(self, event: FailureEvent) -> bool:
        """Record unless an identical (stage, kind, key, action) event
        was already logged -- for per-candidate hot paths where one
        systematic fallback would otherwise flood the log."""
        sig = (event.stage, event.kind, event.key, event.action)
        with self._lock:
            if sig in self._once:
                return False
            self._once.add(sig)
        self.record(event)
        return True

    def events(self, *, stage: Optional[str] = None,
               action: Optional[str] = None) -> List[FailureEvent]:
        evs = [FailureEvent(stage=e.get("stage", ""), kind=e["kind"],
                            key=e.get("key", ""),
                            action=e.get("action", ""),
                            detail=e.get("detail", ""))
               for e in telemetry.events(self.stream)]
        if stage is not None:
            evs = [e for e in evs if e.stage == stage]
        if action is not None:
            evs = [e for e in evs if e.action == action]
        return evs

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events():
            out[e.action] = out.get(e.action, 0) + 1
        return out

    def reset(self) -> None:
        telemetry.clear_events(self.stream)
        with self._lock:
            self._once.clear()


LOG = EventLog()


def record(stage: str, kind: str, key: str, action: str,
           detail: str = "") -> FailureEvent:
    """Record one degradation event in the process-wide ``LOG``."""
    ev = FailureEvent(stage=stage, kind=kind, key=key, action=action,
                      detail=detail)
    LOG.record(ev)
    return ev


def record_once(stage: str, kind: str, key: str, action: str,
                detail: str = "") -> FailureEvent:
    """``record`` deduplicated on (stage, kind, key, action)."""
    ev = FailureEvent(stage=stage, kind=kind, key=key, action=action,
                      detail=detail)
    LOG.record_once(ev)
    return ev


# --------------------------------------------------------------------------
# Deterministic fault injection
# --------------------------------------------------------------------------


class FaultInjector:
    """Deterministic per-site fault schedule.

    ``specs`` maps a site name ("lower", "time", "certify",
    "store-load", ...) to a failure probability in [0, 1].  The n-th
    call at a site fails iff ``sha256(seed|site|n)`` maps below the
    probability -- no global RNG state, so the same env + the same
    call sequence produces the same faults in every process (the
    property the CI chaos smoke and resume-style tests rely on).
    """

    def __init__(self, specs: Optional[Dict[str, float]] = None,
                 seed: int = 0):
        self.specs = dict(specs or {})
        self.seed = seed
        self._counts: Dict[str, int] = {}
        self._lock = threading.Lock()

    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultInjector":
        """Parse ``"lower:0.5,time:1,certify:0.25"`` (an entry without
        a probability means 1.0).  Malformed entries raise ValueError
        -- a typo'd chaos config must not silently inject nothing."""
        specs: Dict[str, float] = {}
        for part in text.split(","):
            part = part.strip()
            if not part:
                continue
            site, _, prob = part.partition(":")
            site = site.strip()
            if not site:
                raise ValueError(f"REPRO_FAULTS: empty site in {text!r}")
            p = float(prob) if prob.strip() else 1.0
            if not 0.0 <= p <= 1.0:
                raise ValueError(
                    f"REPRO_FAULTS: probability {p} for site "
                    f"{site!r} outside [0, 1]")
            specs[site] = p
        return cls(specs, seed=seed)

    @classmethod
    def from_env(cls) -> "FaultInjector":
        text = os.environ.get("REPRO_FAULTS", "")
        seed = int(os.environ.get("REPRO_FAULTS_SEED", "0") or 0)
        return cls.parse(text, seed=seed) if text else cls()

    def maybe_fail(self, site: str, detail: str = "") -> None:
        p = self.specs.get(site, 0.0)
        if p <= 0.0:
            return
        with self._lock:
            n = self._counts.get(site, 0)
            self._counts[site] = n + 1
        raw = f"{self.seed}|{site}|{n}".encode()
        u = int.from_bytes(hashlib.sha256(raw).digest()[:8],
                           "big") / 2.0 ** 64
        if u < p:
            raise InjectedFault(site, detail or f"call #{n}")


# ambient injector parsed lazily from REPRO_FAULTS; cached on the env
# string so the counter sequence survives across calls within one
# process but a monkeypatched env takes effect immediately
_ambient: Tuple[str, Optional[FaultInjector]] = ("", None)
_ambient_lock = threading.Lock()


def ambient_injector() -> FaultInjector:
    global _ambient
    text = os.environ.get("REPRO_FAULTS", "")
    with _ambient_lock:
        if _ambient[1] is None or _ambient[0] != text:
            seed = int(os.environ.get("REPRO_FAULTS_SEED", "0") or 0)
            _ambient = (text, FaultInjector.parse(text, seed=seed)
                        if text else FaultInjector())
        return _ambient[1]


def inject(site: str, detail: str = "") -> None:
    """Fault hook: raise ``InjectedFault`` when the ambient
    ``REPRO_FAULTS`` schedule says this call at this site fails.
    A no-op (one dict lookup) when no faults are configured."""
    ambient_injector().maybe_fail(site, detail)


# --------------------------------------------------------------------------
# Policy: deadlines, retries, certification
# --------------------------------------------------------------------------


def _env_float(name: str, default: float) -> float:
    raw = os.environ.get(name)
    if raw is None or not raw.strip():
        return default
    try:
        return float(raw)
    except ValueError:
        warnings.warn(f"{name}={raw!r} is not a number; using "
                      f"default {default}", stacklevel=2)
        return default


@dataclasses.dataclass(frozen=True)
class Policy:
    """Fault-tolerance policy threaded through the tuning entry points.

    ``timeout_s``: wall-clock deadline per guarded candidate step
    (lower+compile+time); ``<= 0`` disables the deadline.
    ``retries``: extra attempts for *transient* failures only.
    ``backoff_s``: base sleep before retry ``i`` (``backoff_s * 2**i``).
    ``certify``: numerically validate measured winners against the
    oracle before they are promoted into the DSE cache.
    """

    timeout_s: float = 120.0
    retries: int = 1
    backoff_s: float = 0.05
    certify: bool = True


def default_policy() -> Policy:
    """Policy from the environment (``REPRO_TIMEOUT_S`` /
    ``REPRO_RETRIES`` / ``REPRO_BACKOFF_S`` / ``REPRO_CERTIFY``)."""
    return Policy(
        timeout_s=_env_float("REPRO_TIMEOUT_S", 120.0),
        retries=int(_env_float("REPRO_RETRIES", 1)),
        backoff_s=_env_float("REPRO_BACKOFF_S", 0.05),
        certify=os.environ.get("REPRO_CERTIFY", "1").strip()
        not in ("0", "false", "no"),
    )


def resolve_policy(policy: Optional[Policy]) -> Policy:
    """``None`` -> the env-derived default, else the given policy."""
    return default_policy() if policy is None else policy


def run_with_deadline(fn: Callable[[], object], timeout_s: float,
                      *, label: str = "") -> object:
    """``fn()`` bounded by a wall-clock deadline.

    The work runs in a daemon worker thread; when it misses the
    deadline, ``DeadlineExceeded`` is raised and the worker is
    *abandoned* (Python cannot kill a thread wedged inside a C
    extension -- the hung compile keeps its thread, but the explorer
    moves on, which is the degradation the tuning loop needs).
    ``timeout_s <= 0`` runs inline with no deadline.
    """
    if timeout_s is None or timeout_s <= 0:
        return fn()
    out: "queue.Queue" = queue.Queue(maxsize=1)

    def work():
        try:
            out.put((True, fn()))
        except BaseException as exc:  # propagated to the caller below
            out.put((False, exc))

    t = threading.Thread(target=work, daemon=True,
                         name=f"deadline:{label or 'candidate'}")
    t.start()
    try:
        ok, val = out.get(timeout=timeout_s)
    except queue.Empty:
        raise DeadlineExceeded(
            f"{label or 'candidate'} exceeded {timeout_s:g}s deadline"
        ) from None
    if ok:
        return val
    raise val


def call_guarded(fn: Callable[[], object], *, stage: str, key: str,
                 policy: Optional[Policy] = None) -> object:
    """Run one candidate step under the policy's deadline + retry.

    Expected failures (``EXPECTED_ERRORS`` + injected faults) are
    classified and re-raised as ``CandidateFailure`` -- the caller
    quarantines and continues.  Transient kinds are retried
    ``policy.retries`` times with exponential backoff first (each
    retry recorded as an event).  Unexpected exceptions propagate
    unchanged: a real bug must surface, not be quarantined.
    """
    pol = resolve_policy(policy)
    attempts = max(int(pol.retries), 0) + 1
    last: Optional[BaseException] = None
    for attempt in range(attempts):
        try:
            return run_with_deadline(fn, pol.timeout_s, label=key)
        except (InjectedFault,) + EXPECTED_ERRORS as exc:
            kind = classify(exc)
            last = exc
            if kind in RETRYABLE_KINDS and attempt + 1 < attempts:
                record(stage, kind, key, "retried",
                       f"attempt {attempt + 1}/{attempts}: {exc}")
                time.sleep(pol.backoff_s * (2 ** attempt))
                continue
            raise CandidateFailure(kind, str(exc)) from exc
    raise CandidateFailure(classify(last), str(last)) from last


# --------------------------------------------------------------------------
# Crash-safe persistent stores (checksummed + locked + quarantining)
# --------------------------------------------------------------------------


def _payload_checksum(data: Dict) -> str:
    raw = json.dumps(data, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(raw.encode()).hexdigest()[:16]


class _FileLock:
    """Best-effort advisory lock on ``<path>.lock`` (fcntl where
    available).  Lock failures degrade to unlocked operation -- the
    stores are accelerators; losing an update race is acceptable,
    corrupting a reader is not (atomic replace prevents that)."""

    def __init__(self, path: str):
        self.path = path + ".lock"
        self._fd: Optional[int] = None

    def __enter__(self) -> "_FileLock":
        try:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            self._fd = os.open(self.path, os.O_CREAT | os.O_RDWR, 0o644)
            import fcntl
            fcntl.flock(self._fd, fcntl.LOCK_EX)
        except (OSError, ImportError):
            if self._fd is not None:
                try:
                    os.close(self._fd)
                except OSError:
                    pass
                self._fd = None
        return self

    def __exit__(self, *exc) -> None:
        if self._fd is not None:
            try:
                import fcntl
                fcntl.flock(self._fd, fcntl.LOCK_UN)
            except (OSError, ImportError):
                pass
            try:
                os.close(self._fd)
            except OSError:
                pass
            self._fd = None


def atomic_write_json(path: str, doc, *, prefix: str = ".tmp.",
                      indent: int = 0) -> None:
    """mkstemp + rename JSON write shared by the persistent stores.
    An ``OSError`` (read-only FS etc.) is swallowed: every store is an
    accelerator whose callers keep their in-memory copy, never a
    correctness dependency."""
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                   prefix=prefix)
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, indent=indent, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass


def quarantine_file(path: str, *, label: str = "store",
                    reason: str = "corrupt") -> Optional[str]:
    """Move a damaged store to ``<path>.corrupt`` (never deleted: the
    evidence survives for forensics) and warn, naming the file.
    Returns the quarantine path, or None when the move failed."""
    dst = path + ".corrupt"
    try:
        os.replace(path, dst)
    except OSError:
        dst = None
    warnings.warn(
        f"{label} at {path} is {reason}; "
        + (f"quarantined to {dst}" if dst else "quarantine move failed")
        + " -- rebuilding fresh", stacklevel=3)
    record("store", f"store-{reason}", path, "rebuilt",
           f"{label} quarantined to {dst or '<unmoved>'}")
    return dst


def load_store(path: str, *, label: str = "store",
               version: int = STORE_VERSION) -> Dict:
    """Load a persistent JSON store, surviving every corruption mode.

    Accepts both the checksummed envelope (``{"__meta__": {...},
    "data": {...}}``) and the legacy flat-dict format (pre-envelope
    files carry no checksum to verify).  Truncated / garbage JSON, a
    non-dict document, or a checksum mismatch quarantines the file to
    ``<path>.corrupt`` (with a warning naming it) and returns an empty
    store.  A version-skewed envelope is ignored -- fresh store, no
    quarantine: the file is healthy, just written by a different
    revision.  Missing file -> empty store, silently.
    """
    inject("store-load", path)
    try:
        with open(path) as f:
            text = f.read()
    except OSError:
        return {}
    try:
        doc = json.loads(text)
    except ValueError:
        quarantine_file(path, label=label, reason="invalid JSON")
        return {}
    if not isinstance(doc, dict):
        quarantine_file(path, label=label,
                        reason=f"a {type(doc).__name__}, not an object")
        return {}
    meta = doc.get("__meta__")
    if meta is None:
        return doc  # legacy flat format: no checksum to verify
    data = doc.get("data")
    if not isinstance(meta, dict) or not isinstance(data, dict):
        quarantine_file(path, label=label, reason="malformed envelope")
        return {}
    if int(meta.get("version", -1)) != int(version):
        record("store", "store-version-skew", path, "skipped",
               f"{label}: on-disk v{meta.get('version')} != "
               f"expected v{version}")
        return {}
    want = meta.get("checksum")
    if want is not None and want != _payload_checksum(data):
        quarantine_file(path, label=label, reason="checksum mismatch")
        return {}
    return data


def save_store(path: str, data: Dict, *, prefix: str = ".tmp.",
               version: int = STORE_VERSION, indent: int = 0) -> None:
    """Atomically persist ``data`` in the checksummed envelope."""
    doc = {"__meta__": {"version": int(version),
                        "checksum": _payload_checksum(data)},
           "data": data}
    atomic_write_json(path, doc, prefix=prefix, indent=indent)


def locked_update(path: str, mutate: Callable[[Dict], None], *,
                  label: str = "store", prefix: str = ".tmp.",
                  version: int = STORE_VERSION, indent: int = 0) -> Dict:
    """Read-modify-write one store under its file lock.

    Re-reads the on-disk state inside the lock (so two processes
    updating different keys both land, instead of the last writer
    clobbering the first), applies ``mutate(data)`` in place, writes
    atomically, and returns the merged payload.
    """
    with _FileLock(path):
        data = load_store(path, label=label, version=version)
        mutate(data)
        save_store(path, data, prefix=prefix, version=version,
                   indent=indent)
    return data


# --------------------------------------------------------------------------
# Plan certification: measured winners vs the codegen_jax oracle
# --------------------------------------------------------------------------


# dtype-aware comparison tolerances: fp32 matches the repo-wide 2e-3
# test tolerance; half precisions accumulate ~10x looser; integer and
# boolean outputs must be exact (a fold over int data has one answer).
_TOLERANCES = {
    "float32": (2e-3, 2e-3), "float64": (1e-6, 1e-6),
    "bfloat16": (2e-2, 2e-2), "float16": (2e-2, 2e-2),
}


def tolerances(dtype) -> Tuple[float, float]:
    """(rtol, atol) for certifying outputs of the given dtype;
    (0, 0) -- exact -- for integer/bool dtypes."""
    name = str(dtype)
    if name in _TOLERANCES:
        return _TOLERANCES[name]
    import numpy as np
    try:
        if np.issubdtype(np.dtype(name), np.floating):
            return (2e-3, 2e-3)
    except TypeError:
        pass
    return (0.0, 0.0)


def _outputs_match(got, want) -> Tuple[bool, str]:
    import numpy as np

    got = np.asarray(got)
    want = np.asarray(want)
    if got.shape != want.shape:
        return False, f"shape {got.shape} != {want.shape}"
    rtol, atol = tolerances(want.dtype)
    if np.allclose(got, want, rtol=rtol, atol=atol, equal_nan=True):
        return True, "ok"
    err = float(np.max(np.abs(np.asarray(got, dtype="float64")
                              - np.asarray(want, dtype="float64"))))
    return False, (f"max_abs_err={err:.3e} beyond rtol={rtol} "
                   f"atol={atol} for dtype {want.dtype}")


def certify_tile_plan(p, sizes: Dict[str, Tuple[int, ...]], *,
                      vmem_budget: Optional[int] = None,
                      seed: int = 0) -> Tuple[bool, str]:
    """Numerically validate one tile-size candidate of pattern ``p``
    against the ``codegen_jax`` oracle of the *untiled* program.

    The candidate lowers exactly as the timing path does
    (``codegen_pallas.lower_for_timing``); an ``"oracle"`` lowering is
    certified by construction (it IS the reference executable).
    Returns ``(ok, reason)``; exceptions during certification count as
    failure (a kernel that cannot even run its validation input must
    not be promoted).
    """
    import jax

    from . import ir
    from .codegen_jax import execute
    from .codegen_pallas import lower_for_timing
    from .measure import synth_inputs

    with telemetry.span("resilience.certify", kind="tile",
                        key=p.name) as sp:
        inject("certify", type(p).__name__)
        fn, how = lower_for_timing(p, sizes, vmem_budget=vmem_budget,
                                   seed=seed)
        if how == "oracle":
            sp.set(ok=True, how="oracle")
            return True, "oracle lowering is the reference"
        inputs = synth_inputs(ir.inputs_of(p), seed=seed)
        want = jax.jit(lambda **kw: execute(p, kw))(**inputs)
        got = fn()
        if isinstance(want, tuple):
            want = want[0]
        if isinstance(got, tuple):
            got = got[0]
        ok, why = _outputs_match(got, want)
        sp.set(ok=ok, how="pallas")
        return ok, f"pallas-vs-oracle: {why}"


def certify_pipeline_plan(pipe, plan, *,
                          vmem_budget: Optional[int] = None,
                          seed: int = 0) -> Tuple[bool, str]:
    """Validate one fused-pipeline plan candidate against the unfused
    per-stage oracle (``pipeline.run_unfused``), output by output with
    dtype-aware tolerances."""
    from . import pipeline as plmod
    from .codegen_pallas import lower_pipeline_for_timing
    from .measure import synth_inputs

    with telemetry.span("resilience.certify", kind="pipeline",
                        key=pipe.name) as sp:
        inject("certify", pipe.name)
        inputs = synth_inputs(plmod.external_inputs(pipe), seed=seed)
        got = lower_pipeline_for_timing(pipe, plan,
                                        vmem_budget=vmem_budget,
                                        seed=seed)()
        want = plmod.run_unfused(pipe, dict(inputs))
        outs = plmod.output_names(pipe)
        if not isinstance(want, dict):
            want = {outs[0]: want}
        if not isinstance(got, dict):
            got = {outs[0]: got}
        for name, ref in want.items():
            if name not in got:
                sp.set(ok=False)
                return False, f"output {name!r} missing from fused result"
            ok, why = _outputs_match(got[name], ref)
            if not ok:
                sp.set(ok=False)
                return False, f"output {name!r}: {why}"
        sp.set(ok=True)
        return True, "fused-vs-unfused: ok"


def certify_guarded(certify_fn: Callable[[], Tuple[bool, str]], *,
                    key: str, policy: Optional[Policy] = None
                    ) -> Tuple[bool, str]:
    """Run a certification under the policy deadline; any expected
    failure (including a certification hang) reads as *not certified*
    -- an unverifiable winner is treated exactly like a wrong one."""
    try:
        return call_guarded(certify_fn, stage="certify", key=key,
                            policy=policy)
    except CandidateFailure as e:
        return False, f"certification failed ({e.kind}): {e.detail}"
