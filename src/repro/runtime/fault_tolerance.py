"""Fault tolerance: failure detection, elastic rescale, stragglers.

On a real multi-pod deployment these hooks sit on the coordinator
(jax.distributed + the cluster scheduler).  The *policies* are what we
implement and test here, against a simulated cluster -- the decisions
(when to declare a node dead, how to rebuild the mesh, when a straggler
triggers action) are hardware-independent.

Recovery path exercised by tests/test_runtime.py:
  1. heartbeat monitor declares node dead after ``timeout_s``;
  2. ``plan_rescale`` builds the largest usable (data, model) mesh from
     survivors (model-parallel degree preserved if possible -- param
     shards must still fit);
  3. training state restores from the last checkpoint via
     ``checkpoint.restore(..., shardings=new)`` and the data pipeline
     rewinds to the checkpoint step (deterministic stream => no drift);
  4. straggler policy: per-step durations feed an EWMA; a rank slower
     than ``threshold x`` median for ``patience`` steps is flagged for
     eviction (treated as a failure) -- at 1000+ nodes, evict-and-
     rescale beats waiting on a sick host.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Sequence

from ..core import telemetry


@dataclasses.dataclass
class NodeState:
    last_heartbeat: float
    step_ewma: float = 0.0
    slow_count: int = 0
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, nodes: Sequence[str], timeout_s: float = 60.0):
        now = time.monotonic()
        self.timeout_s = timeout_s
        self.nodes: Dict[str, NodeState] = {
            n: NodeState(last_heartbeat=now) for n in nodes}

    def heartbeat(self, node: str, now: Optional[float] = None) -> None:
        self.nodes[node].last_heartbeat = (
            time.monotonic() if now is None else now)

    def sweep(self, now: Optional[float] = None) -> List[str]:
        """Returns newly-dead nodes."""
        now = time.monotonic() if now is None else now
        dead = []
        for name, st in self.nodes.items():
            if st.alive and now - st.last_heartbeat > self.timeout_s:
                st.alive = False
                dead.append(name)
        return dead

    def alive(self) -> List[str]:
        return [n for n, s in self.nodes.items() if s.alive]


@dataclasses.dataclass
class RescalePlan:
    data: int
    model: int
    dropped: int        # healthy devices left idle by shape constraints

    @property
    def devices(self) -> int:
        return self.data * self.model


def plan_rescale(n_devices: int, model_parallel: int = 16,
                 min_model: int = 1) -> RescalePlan:
    """Largest (data x model) grid from ``n_devices`` survivors.

    Preserves the model-parallel degree when possible (param shards keep
    fitting); halves it only when the survivor count cannot fill even
    one model group."""
    mp = model_parallel
    while mp > min_model and n_devices < mp:
        mp //= 2
    data = n_devices // mp
    return RescalePlan(data=data, model=mp,
                       dropped=n_devices - data * mp)


class StragglerPolicy:
    """EWMA step-time tracking; flags ranks persistently slower than
    ``threshold`` x the median."""

    def __init__(self, threshold: float = 1.5, patience: int = 3,
                 alpha: float = 0.3):
        self.threshold = threshold
        self.patience = patience
        self.alpha = alpha
        self.ewma: Dict[str, float] = {}
        self.slow: Dict[str, int] = {}

    def record_step(self, durations: Dict[str, float]) -> List[str]:
        """Feed one step's per-rank durations; returns ranks to evict.
        Evictions are emitted on the unified telemetry event stream."""
        for rank, d in durations.items():
            prev = self.ewma.get(rank, d)
            self.ewma[rank] = (1 - self.alpha) * prev + self.alpha * d
        med = sorted(self.ewma.values())[len(self.ewma) // 2]
        evict = []
        for rank, v in self.ewma.items():
            if v > self.threshold * med:
                self.slow[rank] = self.slow.get(rank, 0) + 1
                if self.slow[rank] >= self.patience:
                    evict.append(rank)
            else:
                self.slow[rank] = 0
        for rank in evict:
            telemetry.emit("recovery", "straggler-evict", rank=rank,
                           ewma_s=self.ewma[rank], median_s=med)
        return evict


class RecoveryLog:
    """Audit trail of fault events (what a coordinator would emit).

    A facade over the single structured event stream in
    ``core.telemetry`` (stream ``"recovery"``): ``record`` emits there
    and ``events`` reads back, so recovery events, resilience
    degradation events and tracing spans all land in one export.  The
    ``record(kind, **info)`` / ``events`` surface is unchanged.
    """

    _ids = itertools.count()

    def __init__(self):
        self._id = next(RecoveryLog._ids)

    def record(self, kind: str, **info):
        telemetry.emit("recovery", kind, log_id=self._id, **info)

    @property
    def events(self) -> List[Dict]:
        return [{k: v for k, v in e.items()
                 if k not in ("stream", "ts", "log_id")}
                for e in telemetry.events("recovery", log_id=self._id)]
