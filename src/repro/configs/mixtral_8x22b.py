"""Mixtral-8x22B [arXiv:2401.04088]: 8 experts top-2, sliding window."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    fsdp=True,  # params exceed per-chip HBM at TP=16: ZeRO-3 shard
    name="mixtral-8x22b", family="moe", n_layers=56, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=16384, vocab=32768,
    activation="swiglu", n_experts=8, top_k=2, moe_layer_period=1,
    sliding_window=4096)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     head_dim=16, d_ff=128, vocab=256, n_experts=4,
                     top_k=2, sliding_window=32, remat=False)
