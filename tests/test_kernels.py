"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.filter_reduce import filter_reduce
from repro.kernels.flash_attention import flash_attention
from repro.kernels.groupby_fold import groupby_fold
from repro.kernels.matmul import matmul
from repro.kernels.ssd_scan import ssd_scan


def _r(key, *shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# auto_tile (DSE) paths -- the tuning cache is isolated per-test by the
# conftest fixture
# --------------------------------------------------------------------
def test_matmul_auto_tile():
    x, y = _r(0, 256, 128), _r(1, 128, 256)
    out = matmul(x, y, auto_tile=True)
    np.testing.assert_allclose(out, ref.matmul(x, y), rtol=2e-5, atol=2e-5)


def test_flash_attention_auto_tile():
    q, k, v = _r(0, 1, 4, 256, 64), _r(1, 1, 2, 256, 64), _r(2, 1, 2, 256, 64)
    out = flash_attention(q, k, v, causal=True, auto_tile=True)
    np.testing.assert_allclose(out, ref.attention(q, k, v, causal=True),
                               rtol=2e-4, atol=2e-4)


def test_ssd_scan_auto_tile():
    x = _r(0, 1, 128, 2, 16)
    dt = jax.nn.softplus(_r(1, 1, 128, 2)) * 0.1
    A = -jax.nn.softplus(_r(2, 2)) - 0.1
    B, C = _r(3, 1, 128, 8), _r(4, 1, 128, 8)
    out = ssd_scan(x, dt, A, B, C, auto_tile=True)
    np.testing.assert_allclose(out, ref.ssd_scan(x, dt, A, B, C),
                               rtol=2e-4, atol=2e-4)


def test_groupby_fold_auto_tile():
    keys = jax.random.randint(jax.random.PRNGKey(0), (512,), 0, 16)
    vals = _r(1, 512, 4)
    out = groupby_fold(keys, vals, 16, auto_tile=True)
    np.testing.assert_allclose(out, ref.groupby_fold(keys, vals, 16),
                               rtol=1e-5, atol=1e-5)


def test_filter_reduce_auto_tile():
    x, w = _r(0, 2048), _r(1, 2048)
    out = filter_reduce(x, w, -0.5, 0.8, auto_tile=True)
    want = ref.filter_reduce(x, jnp.float32(-0.5), jnp.float32(0.8), w)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- matmul
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 128, 64, 128, 64, 64),
    (64, 256, 128, 32, 128, 128),
    (8, 16, 8, 8, 8, 16),
])
def test_matmul_shapes(m, k, n, bm, bn, bk):
    x, y = _r(0, m, k), _r(1, k, n)
    out = matmul(x, y, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(out, ref.matmul(x, y), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    x = _r(2, 64, 64).astype(dtype)
    y = _r(3, 64, 64).astype(dtype)
    out = matmul(x, y, block_m=32, block_n=32, block_k=32)
    rtol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.matmul(x, y), rtol=rtol, atol=rtol)


# ----------------------------------------------------- flash attention
@pytest.mark.parametrize("b,hq,hkv,sq,sk,d,bq,bk", [
    (1, 4, 4, 128, 128, 64, 64, 64),    # MHA
    (2, 8, 2, 128, 128, 32, 128, 64),   # GQA 4:1
    (1, 4, 1, 64, 64, 32, 32, 32),      # MQA
    (1, 2, 2, 64, 256, 32, 64, 64),     # decode-ish: kv longer than q
])
def test_flash_attention_causal(b, hq, hkv, sq, sk, d, bq, bk):
    q, k, v = _r(0, b, hq, sq, d), _r(1, b, hkv, sk, d), _r(2, b, hkv, sk, d)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_sliding_window():
    q, k, v = _r(0, 1, 4, 256, 32), _r(1, 1, 2, 256, 32), _r(2, 1, 2, 256, 32)
    out = flash_attention(q, k, v, causal=True, window=64,
                          block_q=64, block_k=64)
    want = ref.attention(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_noncausal():
    q, k, v = _r(3, 1, 2, 64, 32), _r(4, 1, 2, 64, 32), _r(5, 1, 2, 64, 32)
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    want = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------ ssd scan
@pytest.mark.parametrize("b,s,h,dh,n,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 4, 32, 16, 32),
    (1, 32, 1, 8, 4, 32),   # single chunk
])
def test_ssd_scan(b, s, h, dh, n, chunk):
    x = _r(0, b, s, h, dh)
    dt = jax.nn.softplus(_r(1, b, s, h)) * 0.1
    A = -jax.nn.softplus(_r(2, h)) - 0.1
    B = _r(3, b, s, n)
    C = _r(4, b, s, n)
    out = ssd_scan(x, dt, A, B, C, chunk=chunk)
    want = ref.ssd_scan(x, dt, A, B, C)
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)


# -------------------------------------------------------- groupby fold
@pytest.mark.parametrize("t,k,ew,bt", [(512, 16, 4, 128), (256, 8, 1, 256),
                                       (128, 64, 8, 32)])
def test_groupby_fold(t, k, ew, bt):
    keys = jax.random.randint(jax.random.PRNGKey(0), (t,), 0, k)
    vals = _r(1, t, ew)
    out = groupby_fold(keys, vals, k, block_t=bt)
    np.testing.assert_allclose(out, ref.groupby_fold(keys, vals, k),
                               rtol=1e-5, atol=1e-5)


def test_groupby_fold_1d_values():
    keys = jax.random.randint(jax.random.PRNGKey(2), (256,), 0, 8)
    vals = _r(3, 256)
    out = groupby_fold(keys, vals, 8)
    np.testing.assert_allclose(out, ref.groupby_fold(keys, vals, 8),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- filter reduce
@pytest.mark.parametrize("t,bt", [(2048, 512), (1024, 1024), (512, 128)])
def test_filter_reduce(t, bt):
    x = _r(0, t)
    w = _r(1, t)
    out = filter_reduce(x, w, -0.5, 0.8, block_t=bt)
    want = ref.filter_reduce(x, jnp.float32(-0.5), jnp.float32(0.8), w)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)
