"""Train / prefill / serve step builders + input specs for every
(architecture x shape) cell.  Pure functions of (cfg, shape): the
dry-run lowers them against ShapeDtypeStructs; real runs jit them
against concrete arrays.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeConfig
from repro.core import telemetry
from repro.models import model
from repro.models.config import ModelConfig
from repro.optim import adamw


# ------------------------------------------------------------ input specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train/prefill: the token batch (+ frontend stub embeddings).
    decode: one new token (+ scalar position index); the KV cache is a
    separate donated argument (see cache specs).
    """
    gb, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.n_codebooks:
            toks = jax.ShapeDtypeStruct((gb, s, cfg.n_codebooks), i32)
        elif cfg.family == "vlm":
            toks = jax.ShapeDtypeStruct((gb, s - cfg.frontend_tokens), i32)
        else:
            toks = jax.ShapeDtypeStruct((gb, s), i32)
        out = {"tokens": toks}
        if cfg.family == "vlm":
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (gb, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct(toks.shape, i32)
        return out
    # decode
    if cfg.n_codebooks:
        toks = jax.ShapeDtypeStruct((gb, 1, cfg.n_codebooks), i32)
    else:
        toks = jax.ShapeDtypeStruct((gb, 1), i32)
    return {"tokens": toks}


def decode_extras(cfg: ModelConfig, shape: ShapeConfig):
    cache = model.cache_specs(cfg, shape.global_batch, shape.seq_len)
    index = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, index


# ------------------------------------------------------------ step fns
def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    microbatches: int = 1, grad_shardings=None):
    """Train step, optionally with gradient accumulation: the global
    batch splits into ``microbatches`` slices processed sequentially
    (scan), with fp32 grad accumulators sharded like the params.  Peak
    activation/temp memory drops ~linearly; total FLOPs/bytes are
    unchanged -- this is what makes the 72B train_4k cell *fit* 16 GB
    HBM (EXPERIMENTS.md §Perf).

    ``grad_shardings`` (a pytree of NamedShardings like the params) pins
    the fp32 accumulators carried through the microbatch loop: without
    it GSPMD keeps them only TP-sharded (58 GB of stacked f32 grads for
    qwen2-72b -- the §Perf iteration log has the story)."""
    with telemetry.span("steps.build.train", family=cfg.family,
                        microbatches=microbatches):
        return _make_train_step_body(cfg, opt_cfg, microbatches,
                                     grad_shardings)


def _make_train_step_body(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                          microbatches: int, grad_shardings):
    def _pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(
                lambda p: model.loss(p, cfg, batch))(params)
        else:
            def split(x):
                return x.reshape((microbatches,
                                  x.shape[0] // microbatches)
                                 + x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_step(carry, mslice):
                loss_acc, gacc = carry
                l, g = jax.value_and_grad(
                    lambda p: model.loss(p, cfg, mslice))(params)
                gacc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), gacc, g)
                return (loss_acc + l, _pin(gacc)), None

            zeros = _pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            carry = (jnp.zeros((), jnp.float32), zeros)
            if cfg.unroll:  # dry-run cost extrapolation sees every step
                for i in range(microbatches):
                    carry, _ = acc_step(
                        carry, jax.tree.map(lambda x: x[i], mb))
                loss_sum, gsum = carry
            else:
                (loss_sum, gsum), _ = jax.lax.scan(acc_step, carry, mb)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
        new_params, new_opt = adamw.update(grads, opt_state, params,
                                           opt_cfg)
        return loss, new_params, new_opt

    return train_step


def make_prefill_step(cfg: ModelConfig):
    with telemetry.span("steps.build.prefill", family=cfg.family):
        def prefill_step(params, batch):
            logits = model.forward(params, cfg, batch)
            # serving prefill hands off to decode: only the last
            # position's logits leave the step (full logits never hit
            # HBM as output)
            return logits[:, -1]

        return prefill_step


def make_serve_step(cfg: ModelConfig):
    with telemetry.span("steps.build.serve", family=cfg.family):
        def serve_step(params, cache, tokens, index):
            logits, new_cache = model.decode_step(params, cfg, cache,
                                                  tokens, index)
            logits = model.mask_vocab_pad(logits, cfg)
            # greedy next token (sampling lives in the server loop)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt, new_cache

        return serve_step


def make_cache_prefill_step(cfg: ModelConfig):
    """Prefill a whole prompt block into the decode cache in ONE jitted
    call: ``(params, cache, tokens(B, S[, ncb]), index) -> (next, cache)``
    with ``next`` the greedy token after the final prompt position.

    Attention families run the block through ``decode_step`` directly
    (S tokens written to the cache contiguously, causal within the
    block); recurrent families (ssm, hybrid) carry per-token state, so
    the block scans token-by-token *inside* the jit -- still one
    compiled call per prompt length, not one dispatch per token.  The
    block must not wrap the KV ring buffer; callers chunk long prompts
    at the ring boundary (``launch.serve`` does).
    """
    with telemetry.span("steps.build.cache_prefill", family=cfg.family):
        return _make_cache_prefill_body(cfg)


def _make_cache_prefill_body(cfg: ModelConfig):
    block = cfg.family in ("dense", "moe", "audio", "vlm")

    def _greedy(logits):
        logits = model.mask_vocab_pad(logits, cfg)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    def prefill_cache_step(params, cache, tokens, index):
        if block:
            logits, cache2 = model.decode_step(params, cfg, cache,
                                               tokens, index)
            return _greedy(logits), cache2

        def body(carry, tok):
            cache, i = carry
            # restore the step's token axis the scan consumed
            tok = tok[:, None] if tok.ndim == 1 else tok[:, None, :]
            logits, cache = model.decode_step(params, cfg, cache, tok, i)
            return (cache, i + 1), _greedy(logits)

        xs = jnp.moveaxis(tokens, 1, 0)   # (S, B[, ncb])
        (cache2, _), nxts = jax.lax.scan(
            body, (cache, jnp.asarray(index, jnp.int32)), xs)
        return nxts[-1], cache2

    return prefill_cache_step
