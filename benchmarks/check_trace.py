"""CI trace-smoke validator for the Chrome-trace export.

``benchmarks/run.py`` under ``REPRO_TRACE=1`` writes a
``TRACE_<rev>.json`` next to the BENCH json (``core.telemetry``'s
Chrome trace-event format, loadable in https://ui.perfetto.dev).  This
script asserts the export is structurally sound:

  * the file parses as JSON and has a ``traceEvents`` list;
  * at least one complete ("ph": "X") span named ``dse.explore`` is
    present -- the DSE ran and was traced;
  * every event carries numeric non-negative ``ts`` (and ``dur`` for
    "X" events), and the timed events are in non-decreasing ``ts``
    order (the exporter sorts them; a violation means a clock bug).

Exit 0 on a valid trace, 1 with a diagnostic otherwise.

Usage:
  python benchmarks/check_trace.py bench-artifacts/TRACE_*.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List


def load_trace(path_or_glob: str) -> Dict:
    paths = glob.glob(path_or_glob) or [path_or_glob]
    newest = max(paths, key=lambda p: os.path.getmtime(p)
                 if os.path.exists(p) else 0)
    with open(newest) as f:
        return json.load(f)


def validate(doc: Dict) -> List[str]:
    """List of problems; empty == valid."""
    problems: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["no traceEvents list in the document"]
    if not events:
        return ["traceEvents is empty"]

    spans = [e for e in events if e.get("ph") == "X"]
    explores = [e for e in spans if e.get("name") == "dse.explore"]
    if not explores:
        problems.append(
            f"no complete ('ph': 'X') span named dse.explore among "
            f"{len(spans)} spans -- was REPRO_TRACE=1 set for the "
            f"benchmark run?")

    last_ts = None
    for i, e in enumerate(events):
        if "ts" not in e:
            if e.get("ph") != "M":    # metadata events carry no clock
                problems.append(f"event {i} ({e.get('name')!r}) has "
                                f"no ts")
            continue
        ts = e["ts"]
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i} ({e.get('name')!r}) has bad "
                            f"ts {ts!r}")
            continue
        if e.get("ph") == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"span {i} ({e.get('name')!r}) has "
                                f"bad dur {dur!r}")
        if last_ts is not None and ts < last_ts:
            problems.append(
                f"timestamps not monotone: event {i} "
                f"({e.get('name')!r}) ts={ts} after ts={last_ts}")
            break
        last_ts = ts
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="TRACE_<rev>.json path or glob")
    args = ap.parse_args(argv)
    try:
        doc = load_trace(args.trace)
    except (OSError, ValueError) as e:
        print(f"TRACE CHECK FAILED: cannot load {args.trace}: {e}",
              file=sys.stderr)
        return 1
    problems = validate(doc)
    if problems:
        print(f"TRACE CHECK FAILED ({len(problems)}):", file=sys.stderr)
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        return 1
    events = doc["traceEvents"]
    spans = sum(1 for e in events if e.get("ph") == "X")
    print(f"trace OK: {len(events)} events ({spans} spans, "
          f">=1 dse.explore), timestamps monotone")
    return 0


if __name__ == "__main__":
    sys.exit(main())
