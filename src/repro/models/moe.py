"""Mixture-of-experts FFN sublayer (GShard-style grouped dense dispatch).

Tokens are split into groups (sharded over the data axis); each group
routes its tokens independently to (expert, capacity-slot) positions via
one-hot dispatch/combine tensors, so the whole layer is einsums --
GSPMD-friendly: with experts sharded over the "model" axis the dispatch
einsum lowers to the expert-parallel all-to-all.  The routing count
accumulation is a GroupByFold (the paper's CAM template -- see
kernels/groupby_fold.py for the validated kernel).

Supports Mixtral (8e top-2, every layer) and Llama-4 Maverick (128e
top-1, every other layer, + shared expert).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ModelConfig
from .sharding import hint

GROUP_SIZE = 4096  # tokens per routing group (capacity is per group)


def param_shapes(cfg: ModelConfig, n_moe_layers: int) -> Dict[str, Tuple]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    shapes = {
        "router": (n_moe_layers, d, e),
        "we1": (n_moe_layers, e, d, f),
        "we3": (n_moe_layers, e, d, f),
        "we2": (n_moe_layers, e, f, d),
    }
    if cfg.shared_expert:
        shapes.update({
            "ws1": (n_moe_layers, d, f),
            "ws3": (n_moe_layers, d, f),
            "ws2": (n_moe_layers, f, d),
        })
    return shapes


def capacity(cfg: ModelConfig, group_tokens: int) -> int:
    cap = int(cfg.capacity_factor * group_tokens * cfg.top_k
              / cfg.n_experts)
    return max(8, min(group_tokens, (cap + 7) // 8 * 8))


def moe_ffn(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).  ``p`` holds one layer's slices."""
    b, s, d = x.shape
    n_tok = b * s
    e, k = cfg.n_experts, cfg.top_k
    gsz = min(GROUP_SIZE, n_tok)
    assert n_tok % gsz == 0, (n_tok, gsz)
    g = n_tok // gsz
    cap = capacity(cfg, gsz)
    xt = x.reshape(g, gsz, d)
    xt = hint(xt, "data", None, None)

    gate_logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                             p["router"].astype(jnp.float32))
    topv, topi = jax.lax.top_k(gate_logits, k)               # (g, t, k)
    gates = jax.nn.softmax(topv, axis=-1)

    # slot assignment: rank within each expert's segment, computed by
    # sorting choices by expert id (MegaBlocks-style) -- O(t*k) memory
    # instead of the (t*k, e) one-hot cumsum (537 GB at 1M tokens x 128
    # experts).  This is a GroupByFold over the token stream (the CAM
    # template); the dense-histogram variant lives in router_counts.
    n = gsz * k
    flat_e = topi.reshape(g, n)
    order = jnp.argsort(flat_e, axis=1, stable=True)         # (g, n)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    idx = jnp.arange(n, dtype=jnp.int32)[None, :]
    is_new = jnp.concatenate(
        [jnp.ones((g, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]],
        axis=1)
    seg_start = jax.lax.cummax(jnp.where(is_new, idx, 0), axis=1)
    slot_sorted = idx - seg_start                            # rank in segment
    inv = jnp.argsort(order, axis=1)
    slot = jnp.take_along_axis(slot_sorted, inv,
                               axis=1).reshape(g, gsz, k)
    keep = slot < cap

    # scatter dispatch: tokens land at flat slot e*cap + slot; dropped
    # tokens scatter out of bounds (mode="drop").  This never
    # materializes the (t, e, cap) one-hot dispatch tensor -- the same
    # "don't materialize the full intermediate" move as pattern tiling.
    nslots = e * cap
    dest = jnp.where(keep, topi * cap + slot, nslots)        # (g, t, k)

    def scatter_group(x_g, dest_g):
        buf = jnp.zeros((nslots, d), x_g.dtype)
        for kk in range(k):
            buf = buf.at[dest_g[:, kk]].add(x_g, mode="drop")
        return buf

    ex_in = jax.vmap(scatter_group)(xt, dest)                # (g, e*cap, d)
    ex_in = ex_in.reshape(g, e, cap, d)
    ex_in = hint(ex_in, "data", "model", None, None)
    act = L.activation("silu" if cfg.activation == "swiglu"
                       else cfg.activation)
    h = jnp.einsum("gecd,edf->gecf", ex_in, p["we1"])
    if cfg.activation == "swiglu":
        h = act(h) * jnp.einsum("gecd,edf->gecf", ex_in, p["we3"])
    else:
        h = act(h)
    ex_out = jnp.einsum("gecf,efd->gecd", h, p["we2"])
    ex_out = hint(ex_out, "data", "model", None, None)

    def gather_group(ex_g, dest_g, gates_g):
        # dropped tokens gather zeros (fill mode)
        got = jnp.take(ex_g.reshape(nslots, d), dest_g.reshape(-1),
                       axis=0, mode="fill", fill_value=0)
        got = got.reshape(gsz, k, d)
        return jnp.einsum("tkd,tk->td", got, gates_g.astype(ex_g.dtype))

    yt = jax.vmap(gather_group)(ex_out, dest, gates)         # (g, t, d)

    if cfg.shared_expert:
        hs = act(jnp.einsum("gtd,df->gtf", xt, p["ws1"]))
        if cfg.activation == "swiglu":
            hs = hs * jnp.einsum("gtd,df->gtf", xt, p["ws3"])
        yt = yt + jnp.einsum("gtf,fd->gtd", hs, p["ws2"])

    return yt.reshape(b, s, d).astype(x.dtype)


def router_counts(p: Dict, x: jax.Array, cfg: ModelConfig,
                  use_pallas: bool = False) -> jax.Array:
    """Tokens-per-expert histogram -- the GroupByFold of MoE routing.

    With ``use_pallas`` the validated CAM kernel computes it."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    top1 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if use_pallas:
        from repro.kernels.groupby_fold import groupby_fold
        return groupby_fold(top1, jnp.ones((b * s,), jnp.float32),
                            cfg.n_experts)
    from repro.kernels import ref
    return ref.groupby_fold(top1, jnp.ones((b * s,)), cfg.n_experts)
