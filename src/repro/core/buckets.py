"""Shape bucketing + warm-start re-tuning for the DSE stack.

Tuned plans are keyed on *exact* shapes, so a service facing arbitrary
user shapes either compile-storms (one full exploration per novel
shape) or falls off the tuned path entirely.  This module adds the
middle path, AnyHLS-style specialization classes with best-effort
background refinement:

  * every concrete extent maps to a **bucket** -- the next value on a
    power-of-two-ish ladder ``{s*2^j, s*3*2^(j-1)}`` floored at the
    dtype's sublane multiple ``s`` (``bucket_extent``).  Two shapes in
    one bucket share a specialization class;
  * each completed exploration records its winning plan in a **bucket
    index** inside the tuning-cache document (keyed by a
    shape-independent *family* signature of the pattern / pipeline), so
    the index rides the existing crash-safe store;
  * a cold shape whose family has tuned buckets is served a
    **warm-start plan** immediately: the nearest bucket's plan, its
    tiles re-fitted onto the cold shape's divisor grid
    (``dse.axis_candidates`` -- the existing ragged-tail machinery) and
    re-priced analytically.  No kernel is lowered, nothing is measured,
    nothing is cached -- the warm plan is a loan;
  * a **background re-tune** (daemon thread, bounded by the
    ``resilience.Policy`` deadline, deduplicated per cache key) runs
    the full exploration for the exact shape and promotes its winner
    into the tuning cache -- but only after the winner **certifies**
    against the oracle (``resilience.certify_*``), regardless of
    ``policy.certify``: an unattended background write demands
    validation.  Once promoted, the next request for that shape is an
    exact cache hit.

``STATS`` counts exact hits / warm starts / misses / promotions for
the serving loop and the benchmark's bucket-hit-rate section;
``drain()`` joins outstanding re-tunes (tests, benchmark epilogue).

Enabled per call via ``Options(bucketing=True)`` (or fleet-wide with
``REPRO_BUCKETING=1`` -- read by ``Options.from_env``); ``dse.explore``
/ ``dse.explore_pipeline`` own the call sites.
"""
from __future__ import annotations

import hashlib
import math
import threading
from typing import Callable, Dict, Optional, Tuple

from . import ir, resilience, telemetry

# ---------------------------------------------------------------- buckets


def bucket_extent(n: int, *, sublane: int = 1) -> int:
    """Smallest ladder value >= ``n`` from ``{s*2^j, s*3*2^(j-1)}``
    (``s`` = the dtype sublane multiple): powers of two plus their 1.5x
    midpoints, so consecutive buckets are at most 33% apart and every
    bucket is sublane-aligned.  ``n <= s`` collapses to ``s``."""
    n = max(int(n), 1)
    s = max(int(sublane), 1)
    v = s
    while v < n:
        mid = v + v // 2
        if v % 2 == 0 and mid % s == 0 and mid >= n:
            return mid
        v *= 2
    return v


def _bucket_sig(domains: Dict[str, Tuple[int, ...]]) -> str:
    return ";".join(f"{k}={'x'.join(map(str, v))}"
                    for k, v in sorted(domains.items()))


# ------------------------------------------------------- family signatures


def _device() -> str:
    from . import measure
    return measure.device_kind()


def tile_family(p: ir.Pattern, *, vmem_budget: int, align: int) -> str:
    """Shape-independent identity of a tile exploration: pattern tree
    structure (types, names, domain ranks, dtypes), input tensor ranks
    and dtypes, constraints, device kind.  Deliberately excludes
    extents (that is what buckets vary over) and the calibration
    profile hash (warm starts are heuristic seeds; they must survive
    recalibration)."""
    from . import dse
    parts = tuple((type(q).__name__, q.name, len(q.domain),
                   str(q.dtype), bool(q.strided)) for q in ir.walk(p))
    inputs = tuple((t.name, len(t.shape), str(t.dtype))
                   for t in ir.inputs_of(p))
    raw = repr((dse.MODEL_VERSION, _device(), "tile", parts, inputs,
                int(vmem_budget), int(align)))
    return hashlib.sha256(raw.encode()).hexdigest()[:16]


def tile_buckets(p: ir.Pattern, *, align: int
                 ) -> Dict[str, Tuple[int, ...]]:
    """Per tileable pattern domain, the bucketed extents (mirrors
    ``dse.tile_space``'s iteration: named, untiled, unstrided)."""
    from . import dse
    out: Dict[str, Tuple[int, ...]] = {}
    for q in ir.walk(p):
        if q.strided or not q.domain or q.name in out:
            continue
        sub = dse.dtype_sublane(q.dtype)
        out[q.name] = tuple(bucket_extent(d, sublane=sub)
                            for d in q.domain)
    return out


def pipeline_family(pipe, *, vmem_budget: int, align: int) -> str:
    """Shape-independent identity of a pipeline exploration: per-stage
    structure in topological order plus wiring, device kind and
    constraints (extent-free analogue of ``dse.pipeline_key``)."""
    from . import dse
    from . import pipeline as plmod
    parts = tuple((s.name, type(s).__name__, str(s.dtype), len(s.shape),
                   len(s.domain)) for s in plmod.topo_stages(pipe))
    edges = tuple(sorted(set(plmod._edges(pipe))))
    raw = repr((dse.MODEL_VERSION, _device(), "pipeline", pipe.name,
                parts, edges, tuple(plmod.output_names(pipe)),
                int(vmem_budget), int(align)))
    return hashlib.sha256(raw.encode()).hexdigest()[:16]


def pipeline_buckets(pipe) -> Dict[str, Tuple[int, ...]]:
    from . import dse
    from . import pipeline as plmod
    sub = max(dse.dtype_sublane(s.dtype)
              for s in plmod.topo_stages(pipe))
    return {"extent": (bucket_extent(pipe.shared_extent, sublane=sub),)}


# ------------------------------------------------------------ bucket index


def record_tile(p: ir.Pattern, plan, tc, *, vmem_budget: int,
                align: int) -> None:
    """Register ``plan`` as the donor for its bucket (idempotent: an
    identical existing entry skips the disk write; a newer tuned plan
    for the same bucket overwrites -- latest wins)."""
    doms = tile_buckets(p, align=align)
    if not doms:
        return
    fam = tile_family(p, vmem_budget=vmem_budget, align=align)
    _put(tc, fam, doms, plan, "tile")


def record_pipeline(pipe, plan, tc, *, vmem_budget: int,
                    align: int) -> None:
    """Register a *fused* pipeline plan as its bucket's donor (split
    plans are not warm-start donors: their cut structure is priced for
    one extent and does not transfer)."""
    if not plan.fused:
        return
    fam = pipeline_family(pipe, vmem_budget=vmem_budget, align=align)
    _put(tc, fam, pipeline_buckets(pipe), plan, "pipeline")


def _put(tc, family: str, doms: Dict[str, Tuple[int, ...]], plan,
         kind: str) -> None:
    sig = _bucket_sig(doms)
    entry = {"kind": kind,
             "domains": {k: list(v) for k, v in doms.items()},
             "plan": plan.to_json()}
    if tc.bucket_entries(family).get(sig) == entry:
        return
    tc.bucket_put(family, sig, entry)


def _nearest(entries: Dict[str, Dict],
             want: Dict[str, Tuple[int, ...]],
             kind: str) -> Optional[Dict]:
    """The compatible entry whose bucket is log-nearest to ``want``
    (exact bucket first, then donors >= on every dim -- shrinking a
    tuned tile onto a smaller shape loses less than growing one)."""
    best = None
    best_rank: Tuple = ()
    for _sig, e in entries.items():
        if e.get("kind") != kind:
            continue
        doms = {k: tuple(v) for k, v in e.get("domains", {}).items()}
        if set(doms) != set(want) or any(
                len(doms[k]) != len(want[k]) for k in want):
            continue
        dist = sum(abs(math.log2(max(a, 1)) - math.log2(max(b, 1)))
                   for k in sorted(want)
                   for a, b in zip(doms[k], want[k]))
        ge = all(a >= b for k in want
                 for a, b in zip(doms[k], want[k]))
        rank = (dist > 0, not ge, dist)
        if best is None or rank < best_rank:
            best, best_rank = e, rank
    return best


# -------------------------------------------------------------- warm start


def warm_start_tile(p: ir.Pattern, tc, *, vmem_budget: int, align: int):
    """A ``TilePlan`` adapted from the nearest tuned bucket, or None.

    The donor's per-domain tile is mapped onto the cold shape's own
    candidate grid: the largest ``axis_candidates`` divisor <= the
    donor tile (the ragged tail falls out of the divisor enumeration),
    at the donor's buffer depth, re-priced analytically.  Zero
    lowering, zero measurement; the plan is flagged ``warm_start`` and
    never persisted."""
    from . import dse
    want = tile_buckets(p, align=align)
    if not want:
        return None
    fam = tile_family(p, vmem_budget=vmem_budget, align=align)
    entry = _nearest(tc.bucket_entries(fam), want, "tile")
    if entry is None:
        return None
    donor = dse.TilePlan.from_json(entry["plan"])
    sizes: Dict[str, Tuple[int, ...]] = {}
    for q in ir.walk(p):
        if q.strided or not q.domain or q.name in sizes:
            continue
        dt = donor.sizes.get(q.name)
        if dt is None or len(dt) != len(q.domain):
            return None
        sub = dse.dtype_sublane(q.dtype)
        fitted = []
        for extent, want_tile in zip(q.domain, dt):
            cands = dse.axis_candidates(extent, align, sublane=sub)
            le = [c for c in cands if c <= want_tile]
            fitted.append(max(le) if le else min(cands))
        sizes[q.name] = tuple(fitted)
    priced = dse.price(p, sizes, vmem_budget=vmem_budget,
                       profile=False, depth=donor.depth)
    if priced is None:
        return None
    return dse.TilePlan(
        sizes=sizes, depths={k: int(donor.depth) for k in sizes},
        traffic_words=priced.traffic_words,
        vmem_bytes=priced.vmem_bytes,
        modeled_seconds=priced.calibrated_seconds,
        warm_start=True,
        bucket=_bucket_sig({k: tuple(v) for k, v
                            in entry["domains"].items()}))


def warm_start_pipeline(pipe, tc, *, vmem_budget: int, align: int,
                        max_points: int):
    """A fully fused ``PipelinePlan`` adapted from the nearest tuned
    bucket (donor block re-fitted to the cold extent's divisors,
    donor depth kept, re-priced analytically), or None."""
    from . import dse
    from . import pipeline as plmod
    fam = pipeline_family(pipe, vmem_budget=vmem_budget, align=align)
    entry = _nearest(tc.bucket_entries(fam), pipeline_buckets(pipe),
                     "pipeline")
    if entry is None:
        return None
    donor = dse.PipelinePlan.from_json(entry["plan"])
    cands = dse._pipeline_candidates(pipe, align, max_points)
    le = [c for c in cands if c <= donor.block]
    b = max(le) if le else min(cands)
    n_stages = len(plmod.topo_stages(pipe))
    try:
        whole = plmod.sub_pipeline(pipe, 0, n_stages)
    except (ValueError, NotImplementedError):
        return None
    # profile=None -> uncalibrated analytic pricing; _price_pipeline_group
    # takes a pre-resolved profile (unlike dse.price, which resolves)
    res = dse._price_pipeline_group(
        whole, b, vmem_budget=vmem_budget, profile=None,
        counters={"explored": 0, "pruned": 0}, depth=donor.depth)
    if res is None:
        return None
    words, vmem, _s_ana, s_cal, _steps = res
    return dse.PipelinePlan(
        block=int(b), groups=((0, n_stages),), group_blocks=(int(b),),
        depths=(int(donor.depth),), traffic_words=int(words),
        unfused_traffic_words=plmod.unfused_traffic_words(pipe),
        vmem_bytes=int(vmem), modeled_seconds=float(s_cal),
        warm_start=True,
        bucket=_bucket_sig({k: tuple(v) for k, v
                            in entry["domains"].items()}))


# -------------------------------------------------- background re-tuning

STATS: Dict[str, int] = {}
_LOCK = threading.Lock()
_INFLIGHT: set = set()
_THREADS: list = []


def _zero() -> Dict[str, int]:
    return {"exact_hits": 0, "warm_hits": 0, "misses": 0,
            "retunes": 0, "promotions": 0, "retune_failures": 0}


STATS.update(_zero())


def note(kind: str) -> None:
    with _LOCK:
        STATS[kind] = STATS.get(kind, 0) + 1
    # mirror into the unified metrics registry (always on): the BENCH
    # json and serving stats read bucket activity from telemetry
    telemetry.count(f"bucket.{kind}")


def stats() -> Dict[str, int]:
    with _LOCK:
        return dict(STATS)


def snapshot() -> Dict[str, int]:
    """Point-in-time copy of the counters, for per-call deltas: the
    process-wide ``STATS`` survive across serve invocations, so any
    hit rate quoted for *one* call must diff two snapshots
    (``delta``), not read the globals."""
    return stats()


def delta(before: Dict[str, int]) -> Dict[str, int]:
    """Per-key counter growth since ``before`` (a ``snapshot()``)."""
    now = stats()
    return {k: now.get(k, 0) - before.get(k, 0)
            for k in set(now) | set(before)}


def delta_hit_rate(d: Dict[str, int]) -> float:
    """``hit_rate`` over one ``delta()`` window; 0.0 on no lookups."""
    served = d.get("exact_hits", 0) + d.get("warm_hits", 0)
    total = served + d.get("misses", 0)
    return served / total if total else 0.0


def hit_rate() -> float:
    """(exact + warm) / all lookups under bucketing; 0.0 when unused."""
    s = stats()
    served = s["exact_hits"] + s["warm_hits"]
    total = served + s["misses"]
    return served / total if total else 0.0


def reset_stats() -> None:
    with _LOCK:
        STATS.clear()
        STATS.update(_zero())


def schedule_retune(tag: str, retune: Callable[[], object], *,
                    certify: Callable[[object], Tuple[bool, str]],
                    promote: Callable[[object], None],
                    policy: resilience.Policy) -> Optional[threading.Thread]:
    """Run ``retune()`` on a daemon thread under the policy deadline;
    ``certify(plan)`` gates ``promote(plan)`` -- an uncertified winner
    is discarded and recorded, never promoted.  Deduplicated on
    ``tag`` (one in-flight re-tune per exact cache key); expected
    failures (deadline, lowering, injected faults) degrade to a
    recorded event, unexpected exceptions from the exploration itself
    are still confined to the worker thread but re-recorded as bugs.
    """
    with _LOCK:
        if tag in _INFLIGHT:
            return None
        _INFLIGHT.add(tag)
        STATS["retunes"] += 1
    telemetry.count("bucket.retunes")

    def worker() -> None:
        # the daemon thread gets its own lane in the exported trace
        # (the span records this thread's name/ident)
        with telemetry.span("buckets.retune", tag=tag) as sp:
            try:
                if policy.timeout_s:
                    plan = resilience.run_with_deadline(
                        retune, policy.timeout_s, label=f"retune:{tag}")
                else:
                    plan = retune()
                ok, reason = certify(plan)
                if not ok:
                    note("retune_failures")
                    sp.set(outcome="certify-failed")
                    resilience.record("retune", "certify-failed", tag,
                                      "discarded", reason)
                    return
                promote(plan)
                note("promotions")
                sp.set(outcome="promoted")
            except resilience.EXPECTED_ERRORS as e:
                note("retune_failures")
                sp.set(outcome="abandoned")
                resilience.record("retune", resilience.classify(e), tag,
                                  "abandoned", str(e))
            finally:
                with _LOCK:
                    _INFLIGHT.discard(tag)

    t = threading.Thread(target=worker, daemon=True,
                         name=f"repro-retune-{tag[:24]}")
    with _LOCK:
        _THREADS.append(t)
    t.start()
    return t


def drain(timeout: float = 60.0) -> None:
    """Join outstanding background re-tunes (tests and the benchmark
    epilogue call this before asserting on promotions)."""
    with _LOCK:
        pending = list(_THREADS)
        _THREADS.clear()
    for t in pending:
        t.join(timeout)
