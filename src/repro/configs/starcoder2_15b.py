"""StarCoder2-15B [arXiv:2402.19173; hf]: dense GQA decoder."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense", n_layers=40, d_model=6144,
    n_heads=48, n_kv_heads=4, head_dim=128, d_ff=24576, vocab=49152,
    activation="gelu", rope_theta=1e5)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     head_dim=16, d_ff=128, vocab=256, remat=False)
