"""Activation-sharding hints, decoupled from model code.

Models call ``hint(x, "data", None, "model", None)``; by default this is
the identity.  The launcher installs a mesh-aware constraint function
that (a) checks divisibility of each dim against the mesh axis size and
drops the axis if it does not divide (e.g. 14-head InternVL on a 16-way
model axis), and (b) applies ``jax.lax.with_sharding_constraint``.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_HINT_FN: Optional[Callable] = None


def hint(x, *spec):
    if _HINT_FN is None:
        return x
    return _HINT_FN(x, spec)


def hint_first(x, specs):
    """Apply the first spec whose sharded dims all divide the mesh axes
    (e.g. prefer vocab-sharded logits, fall back to sequence-sharded
    when the vocab is not divisible -- granite's 49155)."""
    if _HINT_FN is None or _CHECK_FN is None:
        return x
    for spec in specs:
        if _CHECK_FN(x, spec):
            return _HINT_FN(x, spec)
    return x


_CHECK_FN: Optional[Callable] = None
_MESH: Optional[Mesh] = None


def model_axis_size() -> Optional[int]:
    """Size of the ambient "model" axis (None outside use_mesh_hints)."""
    return None if _MESH is None else int(_MESH.shape["model"])


@contextlib.contextmanager
def use_mesh_hints(mesh: Mesh):
    """Install divisibility-checked sharding constraints for ``mesh``."""
    global _HINT_FN, _CHECK_FN, _MESH

    def fn(x, spec):
        fixed = []
        for dim, ax in enumerate(spec):
            if ax is None:
                fixed.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if dim < x.ndim and x.shape[dim] % size == 0:
                fixed.append(ax)
            else:
                fixed.append(None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*fixed)))

    def check(x, spec):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if dim >= x.ndim or x.shape[dim] % size != 0:
                return False
        return True

    global _CHECK_FN, _MESH
    prev, prevc, prevm = _HINT_FN, _CHECK_FN, _MESH
    _HINT_FN, _CHECK_FN, _MESH = fn, check, mesh
    try:
        yield
    finally:
        _HINT_FN, _CHECK_FN, _MESH = prev, prevc, prevm
