"""Fused filter->fold megakernel (TPC-H Q6 pipeline, paper Fig. 5b/6).

The two-stage pipeline lowered as ONE ``pallas_call``: the filter stage
masks and weights each record tile into a VMEM scratch buffer (the
pipeline intermediate -- it never touches HBM), and the fold stage
reduces that scratch in place into a revisited scalar accumulator
block.  Compare ``kernels.filter_reduce``, which hand-fuses the
predicate into the reduction: this kernel keeps the two stages distinct
(separate compute, explicit VMEM intermediate), which is exactly the
shape ``core.pipeline`` generates for arbitrary pattern chains.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INTERPRET = True


def _auto_blocks(t: int, measure: Optional[str] = None,
                 policy=None, options=None) -> int:
    from .ops import resolve_plan  # shared memoized selector front door
    bt, _ = resolve_plan("fused_filter_fold", t, measure=measure,
                         policy=policy, options=options)
    return bt


def _ff_kernel(x_ref, w_ref, lo_ref, hi_ref, o_ref, mask_ref):
    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # stage 1 (filter): per-record contribution -> VMEM scratch
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    pred = (x >= lo_ref[0]) & (x < hi_ref[0])
    mask_ref[...] = jnp.where(pred, x * w, 0.0)
    # stage 2 (fold): consume the scratch in place
    o_ref[0, 0] += jnp.sum(mask_ref[...])


def fused_filter_fold(x: jax.Array, weight: jax.Array, lo, hi, *,
                      block_t: int = 1024, auto_tile: bool = False,
                      measure: Optional[str] = None,
                      policy=None, options=None,
                      interpret: Optional[bool] = None) -> jax.Array:
    """``sum(where(lo <= x < hi, x * weight, 0))`` as a fused two-stage
    megakernel.  ``auto_tile=True`` picks ``block_t`` by *joint* DSE on
    the filter+fold pipeline (``core.dse.select_fused_filter_fold_blocks``
    -- one plan for the whole chain, cached on the pipeline signature);
    ``measure="top_k"`` backs it with real timings (hybrid DSE), and
    ``policy`` (a ``core.resilience.Policy``) bounds that measured
    exploration with deadlines, quarantine and plan certification.
    """
    (t,) = x.shape
    if auto_tile:
        block_t = _auto_blocks(t, measure, policy, options)
    block_t = min(block_t, t)
    assert t % block_t == 0
    lo = jnp.asarray([lo], jnp.float32)
    hi = jnp.asarray([hi], jnp.float32)
    out = pl.pallas_call(
        _ff_kernel,
        grid=(t // block_t,),
        in_specs=[
            pl.BlockSpec((block_t,), lambda i: (i,)),
            pl.BlockSpec((block_t,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_t,), jnp.float32)],
        interpret=INTERPRET if interpret is None else interpret,
    )(x, weight, lo, hi)
    return out[0, 0]
