"""Granite-3.0-2B [hf:ibm-granite/granite-3.0-2b-base]: dense GQA."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b", family="dense", n_layers=40, d_model=2048,
    n_heads=32, n_kv_heads=8, head_dim=64, d_ff=8192, vocab=49155, vocab_pad=13,
    activation="swiglu")

SMOKE = CONFIG.with_(vocab_pad=0, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     head_dim=16, d_ff=128, vocab=251, remat=False)
