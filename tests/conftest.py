import pytest


@pytest.fixture(autouse=True)
def _isolated_dse_cache(tmp_path, monkeypatch):
    """Keep the DSE tuning cache per-test: auto_tile paths and the
    autotile front-end default to the persistent on-disk cache, and a
    stale ~/.cache entry must never feed an assertion."""
    monkeypatch.setenv("REPRO_DSE_CACHE", str(tmp_path / "dse.json"))
