"""Unified model configuration covering all assigned architecture
families (dense / ssm / hybrid / moe / audio / vlm backbones)."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | ssm | hybrid | moe | audio | vlm
    n_layers: int
    d_model: int
    vocab: int
    # pad embedding/lm_head rows so the vocab shards over the model axis
    # (Megatron-style); logits for pad columns are masked in the loss
    vocab_pad: int = 0
    # attention (unused for pure ssm)
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None   # SWA (Mixtral)
    # ffn
    d_ff: int = 0
    activation: str = "swiglu"    # swiglu | squared_relu | gelu
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_layer_period: int = 1     # every k-th layer is MoE (Llama-4: 2)
    shared_expert: bool = False   # Llama-4 shared expert
    # ssm (mamba-2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    # hybrid (zamba-2): one shared attention block every k ssm blocks
    shared_attn_every: int = 0
    # modality frontend stub (audio/vlm): prefix embeddings length
    n_codebooks: int = 0          # musicgen: embeddings summed, heads split
    frontend_tokens: int = 0      # internvl: number of patch embeddings
    # numerics / compile
    dtype: str = "bfloat16"
    remat: bool = True
    unroll: bool = False  # unroll layer scan (dry-run cost extrapolation)
    fsdp: bool = False    # additionally shard params over data axes (ZeRO-3)
    # which attention positions shard over "model": set by mesh rules
    tie_embeddings: bool = False

    @property
    def padded_vocab(self) -> int:
        return self.vocab + self.vocab_pad

    @property
    def qk_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # analytic parameter / FLOP counts (roofline §MODEL_FLOPS)
    # ------------------------------------------------------------------
    def param_count(self) -> int:
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        n = 0
        n += v * d                          # embed
        if not self.tie_embeddings:
            n += d * v                      # lm_head
        if self.n_codebooks:
            n += (self.n_codebooks - 1) * v * d  # extra codebook embeds
            n += (self.n_codebooks - 1) * d * v  # extra heads
        per_attn = d * self.qk_dim + 2 * d * self.kv_dim + self.qk_dim * d
        if self.qkv_bias:
            per_attn += self.qk_dim + 2 * self.kv_dim
        ffn_mults = 3 if self.activation == "swiglu" else 2
        per_ffn = ffn_mults * d * f
        per_norms = 2 * d
        if self.family in ("dense", "audio", "vlm"):
            n += L * (per_attn + per_ffn + per_norms)
        elif self.family == "moe":
            n_moe = L // self.moe_layer_period
            n_dense = L - n_moe
            n += L * (per_attn + per_norms)
            n += n_dense * per_ffn
            n += n_moe * (self.n_experts * per_ffn
                          + (per_ffn if self.shared_expert else 0)
                          + d * self.n_experts)   # router
        elif self.family == "ssm":
            n += L * (self._ssm_block_params() + d)
        elif self.family == "hybrid":
            n += L * (self._ssm_block_params() + d)
            n += per_attn + per_ffn + per_norms  # one shared block
        n += d                               # final norm
        return n

    def _ssm_block_params(self) -> int:
        d, di, ns, h = (self.d_model, self.d_inner, self.ssm_state,
                        self.ssm_heads)
        in_proj = d * (2 * di + 2 * ns + h)   # x, z, B, C, dt
        conv = self.ssm_conv * (di + 2 * ns)
        out_proj = di * d
        extra = h + h + di                    # A, D, dt_bias/gate-norm
        return in_proj + conv + out_proj + extra

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top_k + shared expert)."""
        if self.family != "moe":
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        ffn_mults = 3 if self.activation == "swiglu" else 2
        per_ffn = ffn_mults * d * f
        n_moe = L // self.moe_layer_period
        dense_total = self.param_count() - n_moe * (
            self.n_experts * per_ffn
            + (per_ffn if self.shared_expert else 0))
        return dense_total + n_moe * per_ffn * (
            self.top_k + (1 if self.shared_expert else 0))

    def model_flops(self, tokens: int, training: bool = True) -> float:
        """6·N·D (training) or 2·N·D (inference forward)."""
        mult = 6 if training else 2
        return mult * self.active_param_count() * tokens
