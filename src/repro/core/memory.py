"""Memory allocation analysis (paper §5 "Memory Allocation").

Walks the tiled IR and assigns every memory region to a hardware
structure, mirroring Table 4 of the paper with TPU-idiomatic targets:

  statically-sized array (tile copy)    -> Buffer (VMEM alloc / BlockSpec)
  buffer crossing metapipeline stages   -> Double buffer (Pallas grid
                                           pipelining realizes this)
  non-affine access on a dynamic array  -> Cache  (TPU: gather via
                                           dynamic_slice; no tag memory)
  FlatMap output                        -> Parallel FIFO (TPU: mask +
                                           prefix-sum compaction buffer)
  GroupByFold accumulator               -> CAM (TPU: dense one-hot
                                           accumulator, num_keys bound)

The pass also checks the total against the VMEM budget -- on the FPGA
this is BRAM capacity; exceeding it is a compile-time error in both
worlds.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from . import ir
from .cost import VMEM_BYTES


@dataclasses.dataclass
class BufferAlloc:
    name: str
    kind: str          # buffer | double_buffer | cache | fifo | cam_dense
    words: int
    dtype: str
    double_buffered: bool
    ports: int         # readers + writers (template parameterization)


@dataclasses.dataclass
class MemoryPlan:
    buffers: List[BufferAlloc]
    vmem_budget_bytes: int

    @property
    def total_bytes(self) -> int:
        return sum(b.words * np.dtype(b.dtype).itemsize *
                   (2 if b.double_buffered else 1) for b in self.buffers)

    @property
    def fits(self) -> bool:
        return self.total_bytes <= self.vmem_budget_bytes

    def describe(self) -> str:
        lines = [f"{'name':24s} {'kind':14s} {'words':>10s} "
                 f"{'dbl':>4s} {'ports':>5s}"]
        for b in self.buffers:
            lines.append(f"{b.name:24s} {b.kind:14s} {b.words:>10d} "
                         f"{str(b.double_buffered):>4s} {b.ports:>5d}")
        lines.append(f"total {self.total_bytes} B / budget "
                     f"{self.vmem_budget_bytes} B -> "
                     f"{'OK' if self.fits else 'OVERFLOW'}")
        return "\n".join(lines)


def plan_memory(p: ir.Pattern,
                vmem_budget_bytes: int = VMEM_BYTES) -> MemoryPlan:
    buffers: List[BufferAlloc] = []
    readers: Dict[str, int] = {}

    # count readers of each tile copy (port analysis)
    for q in ir.walk(p):
        for a in q.accesses:
            if isinstance(a.src, ir.TileCopy):
                readers[a.src.uid] = readers.get(a.src.uid, 0) + 1

    seen = set()
    idx = [0]

    def visit(q: ir.Pattern, depth: int):
        for tc in q.loads:
            if tc.uid in seen:
                continue
            seen.add(tc.uid)
            # a strided pattern's loads are its metapipeline stages:
            # every buffer crossing a stage boundary double-buffers
            # (WAR avoidance between overlapped outer iterations);
            # hoisted preloads are loop-invariant, so a single copy.
            dbl = q.strided and not tc.hoisted
            kind = "double_buffer" if dbl else "buffer"
            buffers.append(BufferAlloc(
                name=f"{tc.name}#{idx[0]}", kind=kind, words=tc.words,
                dtype=tc.dtype, double_buffered=dbl,
                ports=readers.get(tc.uid, 1) + 1))
            idx[0] += 1
            if isinstance(tc.src, ir.Pattern):
                visit(tc.src, depth + 1)
        for a in q.accesses:
            if isinstance(a.src, ir.Tensor) and not a.affine:
                buffers.append(BufferAlloc(
                    name=f"{a.src.name}_cache#{idx[0]}", kind="cache",
                    words=a.words, dtype=a.src.dtype,
                    double_buffered=False, ports=2))
                idx[0] += 1
            elif isinstance(a.src, ir.Pattern):
                visit(a.src, depth + 1)
        if isinstance(q, ir.GroupByFold) and not q.strided:
            buffers.append(BufferAlloc(
                name=f"{q.name}_acc#{idx[0]}", kind="cam_dense",
                words=int(np.prod(q.shape)), dtype=q.dtype,
                double_buffered=False, ports=2))
            idx[0] += 1
        if isinstance(q, ir.FlatMap) and not q.strided:
            buffers.append(BufferAlloc(
                name=f"{q.name}_fifo#{idx[0]}", kind="fifo",
                words=int(np.prod(q.shape)), dtype=q.dtype,
                double_buffered=False, ports=2))
            idx[0] += 1
        if q.inner is not None:
            visit(q.inner, depth + 1)

    visit(p, 0)
    return MemoryPlan(buffers, vmem_budget_bytes)
