"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) record (produced by repro.launch.dryrun):

  compute_s    = HLO_FLOPs / peak_FLOPs            (per device)
  memory_s     = HLO_bytes / HBM_bw                (per device)
  collective_s = ring wire bytes / (links x link_bw) (per device)

Hardware constants: TPU-v5e-class -- 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI, 4 links/chip usable on a 2-D torus axis pair.
HLO FLOPs/bytes are the scan-extrapolated per-device totals (XLA counts
a while body once; the dry-run recovers multiplicity by compiling 1- and
2-group unrolled variants -- see dryrun.py).
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
N_LINKS = 4


def analyze_record(r: Dict[str, Any]) -> Dict[str, Any]:
    flops = r["cost_per_device_scanned"]["flops"]
    hbm = r["cost_per_device_scanned"]["bytes_accessed"]
    wire = r["collective_wire_bytes_scanned"]["total"]
    n = r["n_devices"]
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = wire / (N_LINKS * LINK_BW)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    # useful fraction: analytic model cost vs what the machine must do
    # at the bound.  Train/prefill are compute-characterized (6ND/2ND);
    # decode is memory-characterized: the analytic floor is one read of
    # (active params + caches + step inputs) per step.
    if r["shape"].startswith(("decode", "long")):
        arg_bytes = r["memory_per_device"]["argument_bytes"]
        model_s = arg_bytes / HBM_BW  # must at least stream the state
    else:
        model_s = r["model_flops"] / n / PEAK_FLOPS
    frac = model_s / bound if bound > 0 else 0.0
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops": r["model_flops"],
        "hlo_flops_total": flops * n,
        "useful_ratio": r["model_flops"] / (flops * n) if flops else 0.0,
        "roofline_fraction": frac,
        "step_s_bound": bound,
        "memory_per_device_gb":
            (r["memory_per_device"]["argument_bytes"]
             + r["memory_per_device"]["temp_bytes"]) / 1e9,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("results", nargs="+",
                    help="JSONL files from repro.launch.dryrun")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = []
    for path in args.results:
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                if "skipped" in r:
                    rows.append({"arch": r["arch"], "shape": r["shape"],
                                 "mesh": r["mesh"],
                                 "skipped": r["skipped"]})
                elif "error" in r:
                    rows.append({"arch": r["arch"], "shape": r["shape"],
                                 "mesh": r["mesh"], "error": r["error"]})
                else:
                    rows.append(analyze_record(r))
    if args.markdown:
        hdr = ("| arch | shape | mesh | compute_s | memory_s | coll_s | "
               "bound | frac | useful | mem GB |")
        print(hdr)
        print("|" + "---|" * 10)
        for a in rows:
            if "skipped" in a:
                print(f"| {a['arch']} | {a['shape']} | {a['mesh']} | "
                      f"SKIP ({a['skipped'][:40]}...) |||||||")
                continue
            if "error" in a:
                print(f"| {a['arch']} | {a['shape']} | {a['mesh']} | "
                      f"ERROR |||||||")
                continue
            print(f"| {a['arch']} | {a['shape']} | {a['mesh']} "
                  f"| {a['compute_s']:.4f} | {a['memory_s']:.4f} "
                  f"| {a['collective_s']:.4f} | {a['dominant']} "
                  f"| {a['roofline_fraction']:.3f} "
                  f"| {a['useful_ratio']:.2f} "
                  f"| {a['memory_per_device_gb']:.1f} |")
    else:
        for a in rows:
            print(json.dumps(a))


if __name__ == "__main__":
    main()
