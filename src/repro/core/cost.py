"""Analytic cost model: main-memory traffic and metapipeline overlap.

Reproduces the accounting of the paper's Fig. 5c ("minimum number of
words read from main memory and on-chip storage ... after each IR
transformation") and the metapipeline throughput model of §6.

Read model ("register promotion"): an access or tile copy is loaded
once per iteration of the loop nest *down to the deepest loop index it
depends on*; loops deeper than that reuse the buffered value.  A copy
with a constant base (``hoisted``) is loaded exactly once -- the Pipe-0
preload of Fig. 6.

Hardware constants are the TPU-v5e-class numbers used across the repo
(197 TFLOP/s bf16, 819 GB/s HBM); the FPGA numbers of the paper map to
the same two-term structure (compute vs. DRAM stream).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


from . import ir
from .affine import AffineMap

HBM_BYTES_PER_S = 819e9
PEAK_FLOPS = 197e12
VMEM_BYTES = 16 * 2 ** 20

# Fixed per-grid-step DMA cost (issue + flight latency) that bandwidth
# accounting misses: a tile's transfer can start at most ``depth - 1``
# outer iterations ahead of its consumer, so a metapipeline with
# buffer depth d hides up to ``(d - 1) x max_stage_seconds`` of it.
# What is left is the *exposed* latency ``metapipeline_time`` charges
# per steady-state step -- the quantity deeper buffering buys down.
DMA_ISSUE_LATENCY_S = 1e-6


@dataclasses.dataclass
class TrafficReport:
    """Main-memory words read per tensor + on-chip words per buffer."""

    reads: Dict[str, int]
    on_chip: Dict[str, int]

    @property
    def total_reads(self) -> int:
        return sum(self.reads.values())

    @property
    def total_on_chip(self) -> int:
        return sum(self.on_chip.values())


def _deepest_dep(amap: AffineMap) -> int:
    deps = amap.dependent_dims()
    return max(deps) if deps else -1


def _probe(index_map, n_in: int) -> Optional[AffineMap]:
    if isinstance(index_map, AffineMap):
        return index_map
    try:
        return AffineMap.probe(index_map, n_in)
    except Exception:
        return None  # non-affine


def _extent_of_dim(levels: List[Tuple[ir.Pattern, int]], dim: int) -> int:
    for p, off in levels:
        if off <= dim < off + len(p.domain):
            return p.domain[dim - off]
    raise KeyError(dim)


def _trips_to(levels: List[Tuple[ir.Pattern, int]], deepest: int) -> int:
    """Product of loop extents from the root down to ``deepest`` incl."""
    t = 1
    for p, off in levels:
        for j, e in enumerate(p.domain):
            if off + j <= deepest:
                t *= e
    return t


def traffic(p: ir.Pattern) -> TrafficReport:
    reads: Dict[str, int] = {}
    on_chip: Dict[str, int] = {}
    buf_idx = [0]

    def visit(q: ir.Pattern, levels):
        off = (levels[-1][1] + len(levels[-1][0].domain)) if levels else 0
        path = levels + [(q, off)]
        stack_len = off + len(q.domain)

        for tc in q.loads:
            if isinstance(tc.src, ir.Tensor):
                amap = _probe(tc.index_map, stack_len)
                if tc.hoisted or (amap is not None
                                  and not amap.dependent_dims()):
                    trips = 1
                else:
                    trips = _trips_to(path, _deepest_dep(amap))
                reads[tc.src.name] = (reads.get(tc.src.name, 0)
                                      + trips * tc.words // tc.reuse)
                on_chip[f"{tc.name}#{buf_idx[0]}"] = tc.words
            else:
                on_chip[f"{tc.name}#{buf_idx[0]}"] = tc.words
                visit(tc.src, path)
            buf_idx[0] += 1

        for a in q.accesses:
            if isinstance(a.src, ir.Tensor):
                amap = _probe(a.index_map, stack_len)
                if amap is None:  # non-affine: every iteration pays
                    trips = _trips_to(path, stack_len - 1)
                else:
                    deep = _deepest_dep(amap)
                    trips = _trips_to(path, deep) if deep >= 0 else 1
                reads[a.src.name] = (reads.get(a.src.name, 0)
                                     + trips * a.words)
                # untiled direct access still needs a window's worth of
                # registers/buffer (the paper's "d" for fused k-means)
                key = f"{a.src.name}_window"
                on_chip[key] = max(on_chip.get(key, 0), a.words)
            elif isinstance(a.src, ir.Pattern):
                visit(a.src, path)
        if q.inner is not None:
            visit(q.inner, path)

    visit(p, [])
    return TrafficReport(reads, on_chip)


# ------------------------------------------------------------------ time
@dataclasses.dataclass
class StageCost:
    name: str
    kind: str            # load | compute | store
    seconds: float


def metapipeline_time(stage_costs: List[StageCost],
                      outer_trips: int, depth: int = 2,
                      dma_latency_s: float = DMA_ISSUE_LATENCY_S
                      ) -> Tuple[float, float]:
    """(sequential, metapipelined) execution time for an outer loop whose
    body is the given stages.

    Sequential = sum per iteration; the metapipeline overlaps stages
    across outer iterations (buffers of depth >= 2), so steady-state
    cost = max stage (plus pipeline fill) plus the *exposed* DMA issue
    latency.  A buffer of depth ``d`` lets a load's DMA be issued up to
    ``d - 1`` iterations ahead, giving it ``(d - 1) x max_stage``
    seconds to land before its consumer needs it; whatever remains of
    ``dma_latency_s`` is charged once per steady-state step (issue
    latencies of concurrent loads overlap each other).  The term
    saturates at zero, so deepening past the point where latency is
    fully hidden buys nothing -- that is what keeps the DSE's optimum
    depth workload-dependent instead of "deeper is always better".
    """
    per_iter = [s.seconds for s in stage_costs]
    seq = outer_trips * sum(per_iter)
    step = max(per_iter)
    exposed = 0.0
    if any(s.kind == "load" for s in stage_costs):
        exposed = max(0.0, dma_latency_s - (max(depth, 1) - 1) * step)
    fill = sum(per_iter) - step
    pipe = fill + outer_trips * (step + exposed)
    return seq, pipe


def stage_seconds_load(words: int, bytes_per_word: int = 4,
                       bw: float = HBM_BYTES_PER_S) -> float:
    return words * bytes_per_word / bw


def stream_seconds(words: int, *, bytes_per_word: int = 4,
                   kind: str = "", steps: int = 1,
                   profile=None) -> float:
    """HBM stream seconds for ``words`` main-memory words.

    Uncalibrated (``profile=None``) this is the datasheet-bandwidth
    stream time every DSE pricing used before measured autotuning.
    With a ``calibrate.CalibrationProfile`` it becomes the *measured*
    prediction: effective tier bandwidth plus the per-pattern launch
    overhead paid once per kernel grid step -- the seam through which
    measured runs feed back into ``traffic``-based pricing.
    """
    if profile is None:
        return words * bytes_per_word / HBM_BYTES_PER_S
    from .calibrate import predicted_seconds
    return predicted_seconds(kind, words * bytes_per_word, steps,
                             profile=profile)


def stage_seconds_compute(flops: float,
                          peak: float = PEAK_FLOPS) -> float:
    return flops / peak


# ------------------------------------------------- serving decode traffic
def dense_decode_traffic_words(batch: int, cache_len: int, kv_heads: int,
                               head_dim: int) -> int:
    """Modeled HBM words one decode step streams through a *dense*
    (unpaged) KV cache: every request reads its full ``cache_len``
    extent of K and V regardless of how many tokens are live, plus the
    new token's K/V write and the query read."""
    kv = 2 * batch * cache_len * kv_heads * head_dim
    token = 2 * batch * kv_heads * head_dim      # K/V append
    q = batch * kv_heads * head_dim
    return kv + token + q


def paged_decode_traffic_words(seq_lens, page_size: int, kv_heads: int,
                               head_dim: int) -> int:
    """Modeled HBM words one decode step streams through the paged
    cache: each request touches only its live pages (``seq_len``
    rounded up to page granularity), so ragged batches stop paying for
    the longest request's extent.  Layouts (split vs. head-interleaved
    fused K/V) move the same words; they differ in stream *count*,
    which ``metapipeline_time`` prices, not in this total."""
    total = 0
    for ln in seq_lens:
        pages = -(-int(ln) // page_size)
        total += 2 * pages * page_size * kv_heads * head_dim
        total += 3 * kv_heads * head_dim         # K/V append + query
    return total
