import pytest


@pytest.fixture(autouse=True)
def _isolated_dse_cache(tmp_path, monkeypatch):
    """Keep the DSE tuning cache, timing DB and calibration profile
    per-test: auto_tile paths default to the persistent on-disk stores,
    and a stale ~/.cache entry (or a calibration profile fitted by an
    earlier run) must never feed an assertion."""
    monkeypatch.setenv("REPRO_DSE_CACHE", str(tmp_path / "dse.json"))
    monkeypatch.setenv("REPRO_TIMING_DB", str(tmp_path / "timing.json"))
    monkeypatch.setenv("REPRO_CALIB_PROFILE",
                       str(tmp_path / "calibration.json"))
    monkeypatch.delenv("REPRO_MEASURE", raising=False)
    monkeypatch.delenv("REPRO_BUCKETING", raising=False)
    # ambient resilience state must not leak into tests: no injected
    # faults, default policy knobs, and a fresh failure-event log
    for var in ("REPRO_FAULTS", "REPRO_FAULTS_SEED", "REPRO_TIMEOUT_S",
                "REPRO_RETRIES", "REPRO_BACKOFF_S", "REPRO_CERTIFY"):
        monkeypatch.delenv(var, raising=False)
    # tracing off by default, and the process-wide telemetry registry
    # (spans, counters, event streams) starts empty for every test
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    from repro.core import buckets, resilience, telemetry
    from repro.kernels import ops
    telemetry.reset()
    resilience.LOG.reset()
    buckets.reset_stats()
    # the plan memo keys on shape only, not the per-test cache path --
    # a plan memoized under one test's cache must not satisfy the next
    ops.clear_plan_memo()
    yield
    # don't let a background re-tune spawned by one test mutate the
    # next test's (re-pointed) caches
    buckets.drain(timeout=10.0)
