"""Serving driver: batched one-call prefill + decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
        --smoke --batch 4 --prompt-len 32 --gen 16

Demonstrates the full inference path (the ``decode_*`` dry-run shapes
lower exactly this ``serve_step``): the whole prompt prefills the cache
in a single jitted call (``steps.make_cache_prefill_step`` -- block
decode for attention families, an in-jit token scan for recurrent
ones), then ``--gen`` tokens greedy-decode one step at a time.

``--prompt-lens 24,100,100,360`` serves a mixed batch: requests are
grouped by prompt length and each group prefills in one call.  With
``--bucketing`` the tuning plans backing each group's attention shape
resolve through the shape-bucket layer (``core.buckets``): a cold
prompt length whose bucket is already tuned is served a warm-start
plan immediately (zero foreground lowering) while a bounded background
re-tune promotes the certified exact-shape winner into the cache.

``--continuous`` switches to continuous batching over a *paged* KV
pool (``models.paged``): requests are admitted into and evicted from a
fixed set of decode slots every step, decode runs as one joint
``paged_decode_step`` (the fused ``decode_attention`` DAG), and the KV
layout / page size come from the joint DSE plan.  The fused Pallas
kernel is certified token-identical against the ``decode_step`` oracle
before serving trusts it.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.core import telemetry
from repro.launch import steps as steps_mod
from repro.models import model


def _prefill(prefill_fn, params, cache, prompt, ring: int,
             index0: int = 0):
    """Prefill ``prompt`` into ``cache`` starting at ``index0``,
    chunking at the KV ring boundary (a block write must not wrap)."""
    plen = prompt.shape[1]
    if plen == 0:
        raise ValueError("cannot prefill a zero-length prompt")
    i, nxt = 0, None
    while i < plen:
        chunk = min(plen - i, ring - ((index0 + i) % ring))
        nxt, cache = prefill_fn(params, cache, prompt[:, i:i + chunk],
                                jnp.int32(index0 + i))
        i += chunk
    return nxt, cache


def _ring_len(cfg, max_len: int) -> int:
    """Slot count of the KV ring buffer (= prompt-chunk bound); the
    recurrent scan path has no ring, so any chunk length works."""
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        return model.cache_specs(cfg, 1, max_len)["k"].shape[3]
    return max_len


def _resolve_group_plans(cfg, lengths: Sequence[int], gen: int
                         ) -> List[Dict]:
    """Resolve the DSE attention plan for each prompt-length group
    through the shape-bucket layer.  Returns per-group provenance:
    did the plan come from the exact tuning cache, a bucket warm
    start, or a fresh exploration?  Each group runs with its own
    ``ln + gen`` cache, so the KV extent is per group -- not the
    global ``max(lens) + gen``."""
    from repro.core import buckets
    from repro.core.options import Options
    from repro.kernels import ops

    opts = Options(bucketing=True)
    head_dim = cfg.head_dim or (cfg.d_model // max(cfg.n_heads, 1))
    # snapshot at entry: the process-wide bucket counters accumulate
    # across serve invocations, so per-call hit rates come from the
    # delta, not the raw totals
    before = buckets.snapshot()
    rows = []
    for plen in lengths:
        t0 = time.time()
        _, plan = ops.resolve_plan("attention", int(plen),
                                   int(plen + gen),
                                   int(head_dim), options=opts)
        rows.append({
            "prompt_len": int(plen),
            "resolve_s": time.time() - t0,
            "warm_start": bool(plan.warm_start),
            "bucket": plan.bucket,
            "cached": bool(plan.cached),
            "sizes": {k: tuple(v) for k, v in plan.sizes.items()},
        })
    d = buckets.delta(before)
    rows.append({"bucket_stats": d,
                 "bucket_hit_rate": buckets.delta_hit_rate(d)})
    return rows


def serve(arch: str, smoke: bool, batch: int, prompt_len: int,
          gen: int, seed: int = 0,
          prompt_lens: Optional[Sequence[int]] = None,
          bucketing: bool = False,
          stats_out: Optional[Dict] = None) -> np.ndarray:
    """Serve ``batch`` requests; returns the (batch, gen) generated
    tokens (requests keep their input order even when mixed prompt
    lengths are re-grouped internally).  ``stats_out``, when given, is
    filled with prefill/decode wall times (benchmark hook)."""
    cfg = get_config(arch, smoke=smoke)
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    lens = list(prompt_lens) if prompt_lens else [prompt_len] * batch
    if len(lens) != batch:
        raise ValueError(f"--prompt-lens gave {len(lens)} lengths for "
                         f"--batch {batch}")
    if min(lens) <= 0:
        raise ValueError(f"prompt lengths must be positive: {lens}")
    prefill_fn = jax.jit(steps_mod.make_cache_prefill_step(cfg),
                         donate_argnums=(1,))
    step_fn = jax.jit(steps_mod.make_serve_step(cfg), donate_argnums=(1,))

    rng = np.random.RandomState(seed)
    tok_shape = ((batch, max(lens), cfg.n_codebooks) if cfg.n_codebooks
                 else (batch, max(lens)))
    prompt_pool = rng.randint(0, cfg.vocab, tok_shape)

    # group requests by prompt length: each group prefills its whole
    # prompt in one call (one compile per distinct length)
    groups: Dict[int, List[int]] = {}
    for r, ln in enumerate(lens):
        groups.setdefault(ln, []).append(r)

    if bucketing:
        for row in _resolve_group_plans(cfg, sorted(groups), gen):
            print("plan:", row)

    out = np.zeros((batch, gen), np.int64)
    prefill_s = decode_s = 0.0
    for ln, rows in sorted(groups.items()):
        gb = len(rows)
        prompt = jnp.asarray(prompt_pool[rows][:, :ln], jnp.int32)
        cache = model.init_cache(cfg, gb, ln + gen)
        ring = _ring_len(cfg, ln + gen)

        t0 = time.time()
        with telemetry.span("serve.prefill", prompt_len=ln, batch=gb):
            nxt, cache = _prefill(prefill_fn, params, cache, prompt, ring)
            jax.block_until_ready(nxt)
        dt = time.time() - t0
        prefill_s += dt
        telemetry.observe("serve.prefill_s", dt)

        group_out = []
        t0 = time.time()
        for i in range(ln, ln + gen):
            if cfg.n_codebooks:
                tok = nxt.reshape(gb, 1, cfg.n_codebooks)
            else:
                tok = nxt.reshape(gb, 1)
            ts = time.time()
            with telemetry.span("serve.decode_step", index=i, batch=gb):
                nxt, cache = step_fn(params, cache, tok, jnp.int32(i))
                group_out.append(np.asarray(nxt))
            telemetry.observe("serve.decode_token_s", time.time() - ts)
        decode_s += time.time() - t0

        toks = np.stack(group_out, axis=1)        # (gb, gen[, ncb])
        if cfg.n_codebooks:
            toks = toks[..., 0]                   # report codebook 0
        out[rows] = toks

    n_groups = len(groups)
    print(f"prefill {sorted(groups)} ({n_groups} group"
          f"{'s' if n_groups > 1 else ''}): {prefill_s:.2f}s; "
          f"decode {gen} tokens: {decode_s:.2f}s "
          f"({decode_s / max(gen, 1) * 1e3:.0f} ms/token)")
    if stats_out is not None:
        stats_out.update(prefill_s=prefill_s, decode_s=decode_s,
                         ms_per_token=decode_s / max(batch * gen, 1)
                         * 1e3)
    return out


def _certify_paged_decode(cfg, params, *, layout: str, page_size: int,
                          prompt_len: int = 5, gen: int = 4,
                          seed: int = 0, policy=None
                          ) -> Tuple[bool, str]:
    """Certify the fused Pallas paged-decode kernel against the
    ``model.decode_step`` oracle token-for-token: one short request is
    decoded greedily through both paths (oracle dense cache sized to
    the page-padded extent so the comparison is exact, not tolerance-
    based).  Runs under the resilience policy's deadline/retry; any
    expected failure or token mismatch returns ``(False, why)`` and
    the caller falls back to the reference paged path."""
    from repro.core import resilience
    from repro.models import paged

    def probe() -> Tuple[bool, str]:
        ln = prompt_len
        cmax = -(-(ln + gen) // page_size) * page_size
        rng = np.random.RandomState(seed)
        prompt = rng.randint(0, cfg.vocab, (1, ln))
        oc = model.init_cache(cfg, 1, cmax)
        pc = paged.PagedKVCache.init(cfg, 1, cmax, page_size=page_size,
                                     layout=layout)
        step_o = jax.jit(steps_mod.make_serve_step(cfg))

        def pstep(params, cache, tok):
            logits, cache = paged.paged_decode_step(
                params, cfg, cache, tok, use_pallas=True)
            logits = model.mask_vocab_pad(logits, cfg)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt, cache

        step_p = jax.jit(pstep)
        to = tp = None
        for i in range(ln + gen - 1):
            tok_o = (prompt[:, i:i + 1] if i < ln
                     else np.asarray(to).reshape(1, 1))
            tok_p = (prompt[:, i:i + 1] if i < ln
                     else np.asarray(tp).reshape(1, 1))
            to, oc = step_o(params, oc,
                            jnp.asarray(tok_o, jnp.int32), jnp.int32(i))
            tp, pc = step_p(params, pc, jnp.asarray(tok_p, jnp.int32))
            if i >= ln - 1 and int(np.asarray(to)[0]) != \
                    int(np.asarray(tp)[0]):
                return False, (f"token mismatch at step {i - ln + 1}: "
                               f"oracle {int(np.asarray(to)[0])} != "
                               f"fused {int(np.asarray(tp)[0])}")
        return True, f"token-identical over {gen} decode steps"

    key = f"paged_decode/{layout}/p{page_size}"
    try:
        return resilience.call_guarded(probe, stage="certify", key=key,
                                       policy=policy)
    except resilience.CandidateFailure as exc:
        return False, f"{exc.kind}: {exc.detail}"


def serve_continuous(arch: str, smoke: bool, slots: int, gen: int,
                     seed: int = 0,
                     prompt_lens: Optional[Sequence[int]] = None,
                     prompt_len: int = 32,
                     page_size: Optional[int] = None,
                     layout: Optional[str] = None,
                     use_pallas: bool = True, certify: bool = True,
                     bucketing: bool = False
                     ) -> Tuple[np.ndarray, Dict]:
    """Continuous-batching serve over one shared paged KV pool.

    ``slots`` concurrent decode lanes share a page pool; each decode
    step first *admits* waiting requests into free slots (batch-1
    dense prefill, then the prefilled K/V is scattered into freshly
    allocated pages) and *evicts* finished ones (pages returned to the
    free list), then runs ONE joint ``paged_decode_step`` over all
    slots.  The KV layout and page size come from the joint DSE plan
    (``ops.resolve_plan("paged_decode", ...)``) unless overridden; the
    fused Pallas kernel is certified against the ``decode_step``
    oracle first and serving falls back to the reference paged path on
    any certification failure (recorded as a resilience event).

    Returns ``(tokens, stats)``: the (n_requests, gen) generated
    tokens in request order, and occupancy/latency/provenance stats.
    """
    from repro.core import resilience
    from repro.core.options import Options
    from repro.kernels import ops
    from repro.models import paged

    cfg = get_config(arch, smoke=smoke)
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"continuous paged serving supports dense/moe attention "
            f"families, not {cfg.family}")
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    lens = list(prompt_lens) if prompt_lens else [prompt_len] * slots
    if min(lens) <= 0:
        raise ValueError(f"prompt lengths must be positive: {lens}")
    n_req = len(lens)
    head_dim = cfg.head_dim or (cfg.d_model // max(cfg.n_heads, 1))
    max_ctx = max(lens) + gen

    # layout x page_size x block resolved jointly by the DSE (bucketed
    # on the padded max length when --bucketing is on)
    opts = Options(bucketing=True) if bucketing else None
    (sel_layout, sel_ps, blk, depth), plan = ops.resolve_plan(
        "paged_decode", int(max_ctx), int(head_dim), options=opts)
    layout = layout or sel_layout
    page_size = int(page_size or sel_ps)

    certified = None
    if use_pallas and certify:
        ok, why = _certify_paged_decode(cfg, params, layout=layout,
                                        page_size=page_size)
        certified = ok
        if not ok:
            resilience.record(
                "certify", "numeric",
                f"paged_decode/{layout}/p{page_size}",
                "fallback-reference", why)
            use_pallas = False

    npm = -(-max_ctx // page_size)
    cache = paged.PagedKVCache.init(cfg, slots, npm * page_size,
                                    page_size=page_size, layout=layout)
    free_pages = list(range(cache.n_pages - 1, 0, -1))  # page 0 reserved
    for s in range(slots):                              # park every slot
        cache = cache.assign_pages(s, [0] * npm, 0)

    prefill_fn = jax.jit(steps_mod.make_cache_prefill_step(cfg),
                         donate_argnums=(1,))

    def _step(params, cache, tok):
        logits, cache = paged.paged_decode_step(params, cfg, cache, tok,
                                                use_pallas=use_pallas)
        logits = model.mask_vocab_pad(logits, cfg)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache

    step_fn = jax.jit(_step, donate_argnums=(1,))

    rng = np.random.RandomState(seed)
    prompt_pool = rng.randint(0, cfg.vocab, (n_req, max(lens)))

    from collections import deque
    queue = deque(range(n_req))
    slot_req: List[Optional[int]] = [None] * slots
    slot_pages: List[List[int]] = [[] for _ in range(slots)]
    slot_done = [0] * slots
    next_tok = np.zeros(slots, np.int32)
    out = np.zeros((n_req, gen), np.int64)
    steps = active_steps = admitted = evicted = 0
    prefill_s = decode_s = 0.0
    dense_words = paged_words = 0   # modeled HBM traffic over the trace

    from repro.core import cost as cost_mod
    hkv = cfg.n_kv_heads

    while queue or any(r is not None for r in slot_req):
        for s in range(slots):                               # admit
            if slot_req[s] is not None or not queue:
                continue
            r = queue[0]
            ln = lens[r]
            need = -(-(ln + gen) // page_size)
            if len(free_pages) < need:
                break
            queue.popleft()
            pages = [free_pages.pop() for _ in range(need)]
            t0 = time.time()
            with telemetry.span("serve.admit", request=r, slot=s,
                                prompt_len=ln, pages=need):
                dcache = model.init_cache(cfg, 1, ln)
                prompt = jnp.asarray(prompt_pool[r:r + 1, :ln],
                                     jnp.int32)
                first, dcache = _prefill(prefill_fn, params, dcache,
                                         prompt, _ring_len(cfg, ln))
                cache = cache.assign_pages(s, pages, ln)
                cache = cache.write_tokens(s, dcache["k"][:, 0, :, :ln],
                                           dcache["v"][:, 0, :, :ln], 0)
                jax.block_until_ready(cache.buffers)
            dt = time.time() - t0
            prefill_s += dt
            telemetry.observe("serve.admit_s", dt)
            telemetry.observe("serve.prefill_s", dt)
            slot_req[s], slot_pages[s], slot_done[s] = r, pages, 0
            next_tok[s] = int(np.asarray(first)[0])
            admitted += 1

        active = [s for s in range(slots) if slot_req[s] is not None]
        # modeled decode traffic for THIS step: a dense continuous
        # server sizes every lane's cache to the longest possible
        # context, the paged pool streams only live pages
        live = [lens[slot_req[s]] + slot_done[s] for s in active]
        dense_words += cfg.n_layers * cost_mod.dense_decode_traffic_words(
            len(active), max_ctx, hkv, head_dim)
        paged_words += cfg.n_layers * cost_mod.paged_decode_traffic_words(
            live, page_size, hkv, head_dim)
        t0 = time.time()
        with telemetry.span("serve.decode_step", step=steps,
                            active=len(active)):
            nxt, cache = step_fn(params, cache,
                                 jnp.asarray(next_tok.reshape(slots, 1)))
            nxt = np.asarray(nxt)
        dt = time.time() - t0
        decode_s += dt
        telemetry.observe("serve.decode_token_s",
                          dt / max(len(active), 1))
        steps += 1
        active_steps += len(active)

        # parked slots wrote their garbage token to reserved page 0;
        # pin their lengths back to zero so they never walk off the
        # page table
        mask = np.zeros(slots, np.int32)
        mask[active] = 1
        cache = cache.replace(seq_lens=cache.seq_lens
                              * jnp.asarray(mask))

        for s in active:
            r = slot_req[s]
            out[r, slot_done[s]] = int(nxt[s])
            next_tok[s] = nxt[s]
            slot_done[s] += 1
            if slot_done[s] == gen:                          # evict
                te = time.time()
                with telemetry.span("serve.evict", request=r, slot=s):
                    free_pages.extend(slot_pages[s])
                    cache = cache.assign_pages(s, [0] * npm, 0)
                    slot_req[s], slot_pages[s] = None, []
                telemetry.observe("serve.evict_s", time.time() - te)
                evicted += 1

    occupancy = active_steps / max(steps * slots, 1)
    tokens_out = n_req * gen
    stats = {
        "layout": layout, "page_size": page_size, "block": int(blk),
        "depth": int(depth), "plan_sizes": dict(plan.sizes),
        "use_pallas": bool(use_pallas), "certified": certified,
        "slots": slots, "requests": n_req, "steps": steps,
        "occupancy": occupancy, "admitted": admitted,
        "evicted": evicted, "prefill_s": prefill_s,
        "decode_s": decode_s,
        "ms_per_token": decode_s / max(tokens_out, 1) * 1e3,
        "modeled_dense_traffic_words": int(dense_words),
        "modeled_paged_traffic_words": int(paged_words),
    }
    print(f"continuous serve: {n_req} requests over {slots} slots, "
          f"{steps} steps, occupancy {occupancy:.2f}; "
          f"layout={layout} page_size={page_size} "
          f"pallas={use_pallas} certified={certified}; "
          f"decode {decode_s:.2f}s "
          f"({stats['ms_per_token']:.1f} ms/token)")
    return out, stats


def _parse_lens(text: Optional[str]) -> Optional[Tuple[int, ...]]:
    if not text:
        return None
    return tuple(int(x) for x in text.split(",") if x.strip())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--prompt-lens", type=str, default=None,
                    help="comma-separated per-request prompt lengths "
                         "(mixed batch; overrides --prompt-len)")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--bucketing", action="store_true",
                    help="resolve tuning plans through the shape-bucket "
                         "warm-start layer and print their provenance")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over a paged KV pool: "
                         "--batch is the slot count, --prompt-lens the "
                         "request trace (admit/evict per decode step)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="override the DSE-selected KV page size "
                         "(--continuous only)")
    ap.add_argument("--layout", choices=("split", "fused"), default=None,
                    help="override the DSE-selected KV layout "
                         "(--continuous only)")
    ap.add_argument("--no-pallas", action="store_true",
                    help="use the reference paged attention instead of "
                         "the fused Pallas kernel (--continuous only)")
    args = ap.parse_args()
    if args.continuous:
        toks, _ = serve_continuous(
            args.arch, args.smoke, args.batch, args.gen,
            prompt_lens=_parse_lens(args.prompt_lens),
            prompt_len=args.prompt_len, page_size=args.page_size,
            layout=args.layout, use_pallas=not args.no_pallas,
            bucketing=args.bucketing)
    else:
        toks = serve(args.arch, args.smoke, args.batch, args.prompt_len,
                     args.gen, prompt_lens=_parse_lens(args.prompt_lens),
                     bucketing=args.bucketing)
    print("generated token block:", toks.shape)


if __name__ == "__main__":
    main()
