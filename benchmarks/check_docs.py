"""Docs link checker: fail CI on broken relative links.

Scans the repo's markdown documentation surface (``README.md`` and
``docs/*.md`` by default) for inline links/images and verifies every
*relative* target resolves to a real file or directory:

* external schemes (http/https/mailto) are ignored;
* pure in-page anchors (``#section``) are checked against the file's
  own headings (GitHub anchor slugs);
* ``path#fragment`` links check the path, and the fragment too when
  the target is a markdown file this run parsed;
* links that escape the repository root (e.g. the README's GitHub
  ``../../actions/...`` badge route, which only exists server-side)
  are reported as skipped, not failed.

Exit status 0 when everything resolves, 1 with a per-link report
otherwise -- the CI docs-check step runs exactly this module.
"""
from __future__ import annotations

import argparse
import glob
import os
import re
import sys

# inline markdown links/images: [text](target) / ![alt](target);
# targets with spaces-in-angle-brackets or titles keep only the path
LINK_RE = re.compile(r"!?\[[^\]]*\]\(\s*<?([^)>\s]+)>?(?:\s+\"[^\"]*\")?\s*\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)
SCHEME_RE = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")


def anchor_slug(heading: str) -> str:
    """GitHub's heading -> anchor rule: lowercase, strip everything but
    word chars/spaces/hyphens, spaces to hyphens (inline code and link
    markup dropped first)."""
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)
    text = text.replace("`", "")
    text = re.sub(r"[^\w\- ]", "", text.lower(), flags=re.UNICODE)
    return text.strip().replace(" ", "-")


def markdown_files(repo_root: str, extra: list) -> list:
    files = []
    readme = os.path.join(repo_root, "README.md")
    if os.path.exists(readme):
        files.append(readme)
    files += sorted(glob.glob(os.path.join(repo_root, "docs", "*.md")))
    for pat in extra:
        files += sorted(glob.glob(os.path.join(repo_root, pat)))
    seen, out = set(), []
    for f in files:
        r = os.path.realpath(f)
        if r not in seen:
            seen.add(r)
            out.append(f)
    return out


def check(repo_root: str, files: list):
    """Returns (broken, skipped): lists of (file, link, reason)."""
    anchors = {}  # realpath -> set of heading slugs
    for f in files:
        with open(f, encoding="utf-8") as fh:
            body = CODE_FENCE_RE.sub("", fh.read())
        anchors[os.path.realpath(f)] = {
            anchor_slug(h) for h in HEADING_RE.findall(body)}

    broken, skipped = [], []
    root = os.path.realpath(repo_root)
    for f in files:
        with open(f, encoding="utf-8") as fh:
            body = CODE_FENCE_RE.sub("", fh.read())
        for target in LINK_RE.findall(body):
            if SCHEME_RE.match(target):
                continue  # http(s)/mailto/etc.
            path, _, frag = target.partition("#")
            if not path:  # in-page anchor
                if frag and anchor_slug(frag) not in anchors.get(
                        os.path.realpath(f), set()) \
                        and frag not in anchors.get(
                            os.path.realpath(f), set()):
                    broken.append((f, target, "missing in-page anchor"))
                continue
            resolved = os.path.realpath(
                os.path.join(os.path.dirname(f), path))
            if not (resolved == root
                    or resolved.startswith(root + os.sep)):
                skipped.append((f, target, "escapes repo root"))
                continue
            if not os.path.exists(resolved):
                broken.append((f, target, "missing file"))
                continue
            if frag and resolved in anchors \
                    and anchor_slug(frag) not in anchors[resolved] \
                    and frag not in anchors[resolved]:
                broken.append((f, target, "missing anchor in target"))
    return broken, skipped


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), ".."),
        help="repository root (default: parent of benchmarks/)")
    ap.add_argument("--glob", action="append", default=[],
                    metavar="PATTERN",
                    help="additional markdown globs relative to root "
                         "(repeatable)")
    args = ap.parse_args(argv)
    root = os.path.realpath(args.root)

    files = markdown_files(root, args.glob)
    if not files:
        print("check_docs: no markdown files found", file=sys.stderr)
        return 1
    broken, skipped = check(root, files)
    for f, link, why in skipped:
        print(f"SKIP  {os.path.relpath(f, root)}: ({link}) -- {why}")
    for f, link, why in broken:
        print(f"BROKEN {os.path.relpath(f, root)}: ({link}) -- {why}")
    n_links = len(broken)
    print(f"check_docs: {len(files)} files, {n_links} broken link(s)"
          f", {len(skipped)} skipped")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
