"""Shared pure-JAX building blocks: norms, RoPE, activations, inits."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def activation(name: str):
    if name == "squared_relu":          # Nemotron-4 / Primer
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return jax.nn.gelu
    if name == "silu":
        return jax.nn.silu
    raise KeyError(name)


def rope(x: jax.Array, positions: jax.Array,
         theta: float = 1e4) -> jax.Array:
    """x: (..., S, H, D) rotary over D; positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..,S,half)
    cos = jnp.cos(ang)[..., None, :]                            # (..,S,1,half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16):
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def causal_conv1d(x: jax.Array, w: jax.Array,
                  state: Optional[jax.Array] = None):
    """Depthwise causal conv (Mamba).  x: (B, S, C); w: (K, C).

    Returns (y, new_state) where state is the last K-1 inputs."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:-2] + (k - 1, x.shape[-1]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=-2)          # (B, S+K-1, C)
    ys = sum(xp[..., i:i + x.shape[-2], :] * w[i] for i in range(k))
    new_state = xp[..., -(k - 1):, :]
    return ys.astype(x.dtype), new_state


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 z_loss: float = 1e-4) -> jax.Array:
    """Mean token cross-entropy with optional z-loss, fp32 accumulate."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1).squeeze(-1)
    loss = lse - gold
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return loss.mean()


def scan_layers(body, carry, xs, unroll: bool = False):
    """lax.scan over stacked layer params, or a Python unroll (used by
    the dry-run's cost extrapolation: XLA cost analysis counts a while
    body once, but counts unrolled layers individually)."""
    import jax

    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        sl = jax.tree.map(lambda t: t[i], xs)
        carry, y = body(carry, sl)
        ys.append(y)
    if ys and ys[0] is not None:
        import jax.numpy as jnp
        ys = jax.tree.map(lambda *ts: jnp.stack(ts), *ys)
    else:
        ys = None
    return carry, ys
