"""Pipeline fusion (core.pipeline + dse.explore_pipeline + the fused
megakernel): the ISSUE-2/ISSUE-3 acceptance surface.

Covers: fused IR structure (chains and fan-out DAGs), fused program ==
codegen_jax oracle == numpy reference for all PIPELINES (including the
multi-output kmeans / gda_moments DAGs and the Map-terminal normalize),
the modeled-traffic win, joint-plan caching (hit on second call,
invalidated on stage change, insensitive to declaration order), the
split fallback when VMEM is tight, and the block-alignment bugfix in
codegen_pallas._block_index_map.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import dse, ir
from repro.core import pipeline as plmod
from repro.core.affine import AffineMap
from repro.core.codegen_jax import execute
from repro.core.codegen_pallas import (_block_index_map,
                                       lower_fused_pipeline)
from repro.patterns.analytics import PIPELINES

ALL = sorted(PIPELINES)


def _setup(name):
    """(pipe, inputs, ref) with ref normalized to {output: array}."""
    pipe, make_inputs, reference = PIPELINES[name]()
    inputs = {k: jnp.asarray(v) for k, v in make_inputs().items()}
    ref = reference(make_inputs())
    if not isinstance(ref, dict):
        ref = {plmod.output_names(pipe)[0]: np.asarray(ref)}
    return pipe, inputs, ref


def _check(pipe, got, ref):
    if not isinstance(got, dict):
        got = {plmod.output_names(pipe)[0]: got}
    assert set(got) >= set(ref)
    for k, want in ref.items():
        np.testing.assert_allclose(np.asarray(got[k]), want,
                                   rtol=2e-3, atol=2e-3)


# ------------------------------------------------------- fused IR shape
@pytest.mark.parametrize("name", ALL)
def test_fuse_structure(name):
    pipe, _, _ = _setup(name)
    fdag = plmod.fuse_dag(pipe, 128)
    producers = set(plmod.intermediate_names(pipe))
    stage_uids = {}
    for _, t in fdag.terminals:
        assert t.strided and len(t.domain) == 1
        for tc in t.loads:
            if isinstance(tc.src, ir.Pattern):
                stage_uids.setdefault(tc.name, set()).add(tc.uid)
        # intermediates are VMEM-resident: no main-memory tensor by
        # that name anywhere in the terminal tree
        assert not (producers & {x.name for x in ir.inputs_of(t)})
        # every external tensor read became a tile copy (no streaming)
        for q in ir.walk(t):
            for a in q.accesses:
                assert not isinstance(a.src, ir.Tensor)
    # one lifted stage per producer, and -- fan-out contract -- a
    # producer referenced from several terminal trees keeps ONE uid
    assert set(stage_uids) == {p + "_stage" for p in producers}
    assert all(len(uids) == 1 for uids in stage_uids.values())


@pytest.mark.parametrize("name", ALL)
def test_fused_ir_matches_oracle_and_reference(name):
    pipe, inputs, ref = _setup(name)
    _check(pipe, plmod.run_unfused(pipe, inputs), ref)
    fdag = plmod.fuse_dag(pipe, 128)
    for oname, t in fdag.terminals:
        np.testing.assert_allclose(np.asarray(execute(t, inputs)),
                                   ref[oname], rtol=2e-3, atol=2e-3)


# --------------------------------------------------- megakernel lowering
@pytest.mark.parametrize("name", ALL)
def test_megakernel_matches_oracle(name):
    pipe, inputs, ref = _setup(name)
    kern = lower_fused_pipeline(pipe, cache=False)
    assert kern.pipeline_plan.fused
    _check(pipe, kern(**inputs), ref)


def test_lower_pipeline_unfused_path():
    pipe, inputs, ref = _setup("tpchq6")
    run = plmod.lower_pipeline(pipe, fused=False)
    _check(pipe, run(**inputs), ref)


def test_lower_pipeline_unfused_multi_output():
    pipe, inputs, ref = _setup("kmeans")
    run = plmod.lower_pipeline(pipe, fused=False)
    _check(pipe, run(**inputs), ref)


# ------------------------------------------------------- traffic model
def test_fused_traffic_at_least_1p5x_lower_on_most():
    ratios = {}
    for name in ALL:
        pipe, _, _ = _setup(name)
        plan = dse.explore_pipeline(pipe, cache=False)
        assert plan.fused
        assert plan.traffic_words < plan.unfused_traffic_words, name
        ratios[name] = plan.traffic_ratio
    assert sum(r >= 1.5 for r in ratios.values()) >= len(ALL) - 1, ratios
    # and the intermediates really contribute zero on the fused path:
    # fused words == external reads + output write
    pipe, _, _ = _setup("tpchq6")
    plan = dse.explore_pipeline(pipe, cache=False)
    n = pipe.shared_extent
    assert plan.traffic_words == 3 * n + 1       # qty/price/disc + scalar
    assert plan.unfused_traffic_words == 5 * n + 1   # + write/read of mask
    # the standalone accounting helpers agree with the joint-DSE plan
    assert plmod.fused_traffic_words(pipe, plan.block) \
        == plan.traffic_words
    assert plmod.unfused_traffic_words(pipe) == plan.unfused_traffic_words


def test_fanout_producer_loaded_once_per_outer_step():
    """kmeans DAG acceptance: the fan-out producer's tiles come from
    VMEM (zero HBM reads for the intermediate), the points tile feeding
    assign AND scatter-sum is DMA'd exactly once per outer step, and
    the fused traffic is strictly below unfused."""
    pipe, _, _ = _setup("kmeans")
    n, block = pipe.shared_extent, 128
    fdag = plmod.fuse_dag(pipe, block)
    assert fdag.refcounts["km_assign"] == 2      # fan-out, ref-counted
    reads = plmod.dag_external_reads(fdag)
    assert "km_assign" not in reads              # never touches HBM
    d = 16
    assert reads["points"] == (n // block) * block * d   # once per step
    assert reads["centroids"] == 8 * d           # Pipe-0 preload, once
    assert plmod.fused_traffic_words(pipe, block) \
        < plmod.unfused_traffic_words(pipe)


def test_fanout_memory_plan_counts_scratch_once():
    """plan_memory over the whole terminal set charges the fan-out
    stage's double-buffered scratch once, with a port per reader."""
    pipe, _, _ = _setup("kmeans")
    mem = plmod.fused_memory_plan(pipe, 128)
    assert mem.fits
    stage = [b for b in mem.buffers if b.name.startswith("km_assign_stage")]
    assert len(stage) == 1
    assert stage[0].double_buffered
    assert stage[0].ports >= 3                   # 2 readers + writer
    # the shared points tile: one buffer despite two terminal trees
    pts = [b for b in mem.buffers if b.name.startswith("points_tile")]
    assert len(pts) == 1


def test_fused_vmem_plan_double_buffers_intermediate():
    pipe, _, _ = _setup("gda")
    mem = plmod.fused_memory_plan(pipe, 128)
    assert mem.fits
    stage = [b for b in mem.buffers if b.name.startswith("gda_feat_stage")]
    assert stage and all(b.double_buffered for b in stage)


def test_schedule_has_stage_and_preload():
    pipe, _, _ = _setup("kmeans")
    mp = plmod.schedule(pipe, 128)
    kinds = [s.kind for s in mp.stages]
    assert "compute" in kinds and "body" in kinds
    assert all(s.double_buffered for s in mp.stages
               if s.kind in ("load", "compute", "body"))
    # centroids are loop-invariant: Pipe-0 preload, single-buffered
    assert any("centroids" in s.name for s in mp.preloads)


# ------------------------------------------------------- joint-plan cache
def test_pipeline_plan_cached_and_replayed(tmp_path):
    path = str(tmp_path / "dse.json")
    pipe, _, _ = _setup("tpchq6")
    plan1 = dse.explore_pipeline(pipe, cache=path)
    assert not plan1.cached
    plan2 = dse.explore_pipeline(pipe, cache=path)
    assert plan2.cached
    assert plan2.block == plan1.block
    assert plan2.groups == plan1.groups
    assert plan2.group_blocks == plan1.group_blocks
    assert plan2.traffic_words == plan1.traffic_words


def test_pipeline_plan_invalidated_on_stage_change(tmp_path):
    from repro.patterns.analytics import tpchq6_pipeline
    path = str(tmp_path / "dse.json")
    pipe, _, _ = tpchq6_pipeline()
    dse.explore_pipeline(pipe, cache=path)
    smaller, _, _ = tpchq6_pipeline(n=2048)
    plan = dse.explore_pipeline(smaller, cache=path)
    assert not plan.cached  # any stage signature change -> new key


def test_pipeline_key_sensitive_to_each_stage():
    pipe, _, _ = _setup("gda")
    k0 = dse.pipeline_key(pipe)
    # change only the *producer* stage's external input (same shapes,
    # same wiring -- the stage signature alone must move the key)
    feat = pipe.stages[0]
    other = ir.Tensor("pts_alt", (pipe.shared_extent, 8))
    feat2 = ir.Map(domain=feat.domain, elem_shape=feat.elem_shape,
                   reads=(ir.Access(other, lambda i: (i, 0), (1, 8)),),
                   fn=feat.fn, name=feat.name)
    pipe2 = plmod.Pipeline(name=pipe.name,
                           stages=(feat2,) + pipe.stages[1:])
    assert dse.pipeline_key(pipe2) != k0


def test_pipeline_key_is_topological():
    """The DSE cache key hashes the DAG, not the declaration order:
    reordering independent stages yields the same key (and the same
    cached plan), while rewiring an edge changes it."""
    pipe, _, _ = _setup("kmeans")
    reordered = plmod.Pipeline(
        name=pipe.name,
        stages=(pipe.stages[0], pipe.stages[2], pipe.stages[1]))
    assert dse.pipeline_key(reordered) == dse.pipeline_key(pipe)
    assert plmod.output_names(reordered) == plmod.output_names(pipe)


# ------------------------------------------------------- split fallback
def test_split_fallback_when_vmem_tight():
    pipe, inputs, ref = _setup("gda")
    # 80 KB: the fully fused kernel (~84 KB at the smallest candidate)
    # busts VMEM but each stage alone fits -> cheapest-cut split
    plan = dse.explore_pipeline(pipe, vmem_budget=80_000, cache=False)
    assert not plan.fused
    assert plan.groups == ((0, 1), (1, 2))
    assert len(plan.group_blocks) == 2   # per-group block sizes
    # the split pays the intermediate round-trip the fused plan deletes
    full = dse.explore_pipeline(pipe, cache=False)
    assert plan.traffic_words > full.traffic_words
    kern = lower_fused_pipeline(pipe, plan=plan, vmem_budget=80_000)
    _check(pipe, kern(**inputs), ref)


def test_no_candidate_raises():
    pipe, _, _ = _setup("tpchq6")
    with pytest.raises(ValueError, match="no tile candidate fits"):
        dse.explore_pipeline(pipe, vmem_budget=64, cache=False)


def test_group_lowerings_report_what_ran():
    pipe, _, _ = _setup("tpchq6")
    kern = lower_fused_pipeline(pipe, cache=False)
    assert kern.group_lowerings == (("q6_sum", "megakernel"),)
    split = dse.explore_pipeline(_setup("gda")[0], vmem_budget=80_000,
                                 cache=False)
    kern2 = lower_fused_pipeline(_setup("gda")[0], plan=split,
                                 vmem_budget=80_000)
    assert len(kern2.group_lowerings) == 2
    # the bare-Map first group now lowers through the write-once
    # streaming template -- a megakernel, not a per-stage fallback
    assert all(how == "megakernel" for _, how in kern2.group_lowerings)


def test_megakernel_scalar_element_groupby():
    """GroupByFold terminal with elem_shape=() (a keyed count): the
    rank-1 (k,) accumulator must pad to a 2-D block like the fold
    template does."""
    n, k = 256, 8
    x = ir.Tensor("x", (n,))
    keymap = ir.Map(domain=(n,), reads=(ir.elem(x),),
                    fn=lambda s, e: jnp.floor(e * k), name="keys")
    hist = ir.GroupByFold(
        domain=(n,), num_keys=k, elem_shape=(),
        init=lambda: jnp.zeros((k,)),
        reads=(ir.elem(ir.Tensor("keys", (n,))),),
        fn=lambda s, ke: (ke.astype(jnp.int32), jnp.float32(1.0)),
        combine=lambda a, b: a + b, name="hist")
    pipe = plmod.Pipeline(name="hist", stages=(keymap, hist))
    rng = np.random.RandomState(3)
    xs = rng.rand(n).astype(np.float32) * 0.999
    ref = np.bincount((xs * k).astype(np.int32), minlength=k
                      ).astype(np.float32)
    kern = lower_fused_pipeline(pipe, cache=False)
    out = np.asarray(kern(x=jnp.asarray(xs)))
    assert out.shape == (k,)
    np.testing.assert_allclose(out, ref, rtol=1e-6)


# ------------------------------------------------------- validation
def test_pipeline_validation_basics():
    x = ir.Tensor("x", (64,))
    m = ir.Map(domain=(64,), reads=(ir.elem(x),),
               fn=lambda s, e: e, name="a")
    bad = ir.Map(domain=(32,), reads=(ir.elem(x),),
                 fn=lambda s, e: e, name="b")
    with pytest.raises(ValueError, match="shared"):
        plmod.Pipeline(name="p", stages=(m, bad))


def test_pipeline_stages_may_be_declared_out_of_order():
    """DAG semantics: declaration order is irrelevant; the consumer may
    precede its producer in ``stages`` (the old chain API raised)."""
    x = ir.Tensor("x", (64,))
    consumer = ir.Map(domain=(64,),
                      reads=(ir.elem(ir.Tensor("z", (64,))),),
                      fn=lambda s, e: e, name="a2")
    z = ir.Map(domain=(64,), reads=(ir.elem(x),),
               fn=lambda s, e: e, name="z")
    pipe = plmod.Pipeline(name="p", stages=(consumer, z))
    assert [s.name for s in plmod.topo_stages(pipe)] == ["z", "a2"]
    assert plmod.output_names(pipe) == ("a2",)


# ---------------------------------------------- kernels.fused_filter_fold
def test_fused_filter_fold_kernel(tmp_path, monkeypatch):
    from repro.kernels.fused_filter_fold import fused_filter_fold
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.rand(2048).astype(np.float32))
    w = jnp.asarray(rng.rand(2048).astype(np.float32))
    lo, hi = 0.1, 0.9
    ref = np.sum(np.where((np.asarray(x) >= lo) & (np.asarray(x) < hi),
                          np.asarray(x) * np.asarray(w), 0.0))
    out = fused_filter_fold(x, w, lo, hi, block_t=256)
    np.testing.assert_allclose(float(out), ref, rtol=1e-5)
    monkeypatch.setenv("REPRO_DSE_CACHE", str(tmp_path / "dse.json"))
    out = fused_filter_fold(x, w, lo, hi, auto_tile=True)
    np.testing.assert_allclose(float(out), ref, rtol=1e-5)


# ------------------------------------------------ kernels.fused_kmeans
def test_fused_kmeans_kernel(tmp_path, monkeypatch):
    from repro.kernels.fused_kmeans import fused_kmeans_step
    n, k, d = 256, 8, 16
    rng = np.random.RandomState(0)
    pts = jnp.asarray(rng.randn(n, d).astype(np.float32))
    cents = jnp.asarray(rng.randn(k, d).astype(np.float32))
    d2 = ((np.asarray(pts)[:, None] - np.asarray(cents)[None]) ** 2
          ).sum(-1)
    idx = d2.argmin(1)
    ref_s = np.zeros((k, d), np.float32)
    ref_c = np.zeros((k,), np.float32)
    for i in range(n):
        ref_s[idx[i]] += np.asarray(pts)[i]
        ref_c[idx[i]] += 1
    sums, counts = fused_kmeans_step(pts, cents, block_n=64)
    np.testing.assert_allclose(np.asarray(sums), ref_s,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(counts), ref_c)
    monkeypatch.setenv("REPRO_DSE_CACHE", str(tmp_path / "dse.json"))
    sums, counts = fused_kmeans_step(pts, cents, auto_tile=True)
    np.testing.assert_allclose(np.asarray(sums), ref_s,
                               rtol=1e-4, atol=1e-4)


# -------------------------------------- _block_index_map alignment bugfix
def test_block_index_map_rejects_misaligned_base():
    # base 8 into 16-wide blocks: offset lands mid-block; previously the
    # dead `or base == 0` arm let nothing through *except* this -- the
    # check now raises instead of silently mis-addressing the DMA
    amap = AffineMap((8,), ((16,),), arity=1)
    with pytest.raises(ValueError, match="block-aligned"):
        _block_index_map(amap, (16,), 1)


def test_block_index_map_rejects_partial_stride():
    amap = AffineMap((0,), ((8,),), arity=1)  # stride 8, tile 16
    with pytest.raises(ValueError, match="partial blocks"):
        _block_index_map(amap, (16,), 1)


def test_block_index_map_accepts_aligned():
    amap = AffineMap((32,), ((16,),), arity=1)
    imap = _block_index_map(amap, (16,), 1)
    assert imap(3) == (5,)  # (32 + 3*16) // 16
