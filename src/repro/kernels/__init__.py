# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# fused_filter_fold is the pipeline-fusion megakernel entry point
# (filter -> fold in one pallas_call, intermediate in VMEM scratch);
# see core/pipeline.py for the general multi-pattern fusion subsystem.
