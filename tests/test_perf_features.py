"""Tests for the §Perf optimization features: they must not change
semantics (microbatching, vocab padding) and the dry-run entry point
must work end to end."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import steps as steps_mod
from repro.models import model
from repro.optim import adamw


def _batch(cfg, b, s, key):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    return {"tokens": toks, "labels": toks}


def test_microbatching_matches_full_batch():
    """mb=4 gradient accumulation == single-batch step (same loss and
    same updated params up to fp32 accumulation order)."""
    cfg = get_config("granite-3-2b", smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig()
    opt = adamw.init(params, opt_cfg)
    batch = _batch(cfg, 8, 32, jax.random.PRNGKey(1))

    s1 = jax.jit(steps_mod.make_train_step(cfg, opt_cfg, microbatches=1))
    s4 = jax.jit(steps_mod.make_train_step(cfg, opt_cfg, microbatches=4))
    l1, p1, _ = s1(params, opt, batch)
    l4, p4, _ = s4(params, opt, batch)
    # each microbatch's mean-loss averages to ~the full-batch mean loss
    assert abs(float(l1) - float(l4)) < 5e-3
    for k in p1:
        np.testing.assert_allclose(
            np.asarray(p1[k], np.float32), np.asarray(p4[k], np.float32),
            atol=5e-2, rtol=5e-2)


def test_vocab_padding_preserves_loss_and_decode():
    """A padded-vocab model with identical real rows computes the same
    loss and the same argmax decode as the unpadded one."""
    cfg0 = get_config("granite-3-2b", smoke=True)
    cfg1 = cfg0.with_(vocab_pad=16)
    p0 = model.init_params(cfg0, jax.random.PRNGKey(0))
    p1 = model.init_params(cfg1, jax.random.PRNGKey(0))
    # copy the real rows so the models agree
    for k in ("embed", "lm_head"):
        arr = np.zeros(p1[k].shape, np.float32)
        if k == "embed":
            arr[:cfg0.vocab] = np.asarray(p0[k], np.float32)
        else:
            arr[:, :cfg0.vocab] = np.asarray(p0[k], np.float32)
        p1[k] = jnp.asarray(arr, p1[k].dtype)
    batch = _batch(cfg0, 2, 16, jax.random.PRNGKey(2))
    l0 = model.loss(p0, cfg0, batch)
    l1 = model.loss(p1, cfg1, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-3)

    step0 = steps_mod.make_serve_step(cfg0)
    step1 = steps_mod.make_serve_step(cfg1)
    c0 = model.init_cache(cfg0, 2, 8)
    c1 = model.init_cache(cfg1, 2, 8)
    tok = jnp.zeros((2, 1), jnp.int32)
    n0, _ = jax.jit(step0)(p0, c0, tok, jnp.int32(0))
    n1, _ = jax.jit(step1)(p1, c1, tok, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(n0), np.asarray(n1))
    assert int(jnp.max(n1)) < cfg0.vocab  # pad rows never win


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """The multi-pod dry-run entry point compiles a real cell (own
    process: XLA_FLAGS must precede jax init)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "internvl2-1b", "--shape", "decode_32k"],
        capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert "error" not in rec
    assert rec["memory_per_device"]["temp_bytes"] > 0
    assert rec["collective_wire_bytes_scanned"]["total"] >= 0
