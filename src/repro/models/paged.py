"""Paged KV cache + decode step on the pattern substrate.

Serving decode was the one hot path still running outside the pattern
stack (a plain jitted ``decode_step``, one dense ``(B, Hkv, C, dh)``
cache per length group).  This module re-expresses it over a *paged*
pool: KV lives in fixed-size pages, each request owns a page list
(``page_table`` row) and a live length (``seq_lens``), and one decode
step is the ``decode_attention`` pipeline DAG -- a KV-append producer
feeding a flash-attention fold over a ragged streaming domain
(``core.ir.RaggedExtent``: static page-count grid, in-kernel length
predication).

Two enumerable KV layouts (the DSE axis ``core.dse.
select_paged_decode_blocks`` searches):

  * ``split``  -- separate K and V pools, each ``(L, P, ps, Hkv, dh)``;
  * ``fused``  -- one pool ``(L, P, ps, 2*Hkv, dh)`` with K and V
    head-interleaved (K at even head index ``2h``, V at odd ``2h+1``),
    so a page streams both operands of one head in a single burst.

``paged_decode_step`` mirrors ``model.decode_step`` structurally (same
``scan_layers`` over stacked params, same einsums and casts, only the
cache write/read swapped for page scatter/gather -- both exact
permutations), so with a no-wrap dense cache of the page-padded extent
the oracle is *bit-identical*, not merely close: the ring mask reduces
to ``slot <= position`` and the gathered view equals the dense cache.
``use_pallas=True`` swaps the reference attention for the fused
``codegen_pallas.lower_paged_decode`` kernel (append + online-softmax
fold in one kernel); serving certifies it against the reference via
``core.resilience`` before trusting it.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as moe_mod
from .config import ModelConfig
from .transformer import (Params, _dense_ffn, _embed_tokens,
                          _layer_stacks)

LAYOUTS = ("split", "fused")


@jax.tree_util.register_pytree_node_class
class PagedKVCache:
    """Blocked KV storage: ``buffers`` is a tuple of page pools
    (``(k_pages, v_pages)`` for split, ``(kv_pages,)`` for fused),
    ``page_table[b]`` the request's logical-page -> physical-page map,
    ``seq_lens[b]`` its live token count.  Physical page 0 is reserved
    as scratch so inactive slots always have somewhere valid to point.
    """

    def __init__(self, buffers: Tuple[jax.Array, ...],
                 page_table: jax.Array, seq_lens: jax.Array, *,
                 layout: str, page_size: int):
        if layout not in LAYOUTS:
            raise ValueError(f"layout {layout!r}; one of {LAYOUTS}")
        self.buffers = tuple(buffers)
        self.page_table = page_table
        self.seq_lens = seq_lens
        self.layout = layout
        self.page_size = page_size

    def tree_flatten(self):
        return ((self.buffers, self.page_table, self.seq_lens),
                (self.layout, self.page_size))

    @classmethod
    def tree_unflatten(cls, aux, children):
        buffers, page_table, seq_lens = children
        return cls(buffers, page_table, seq_lens,
                   layout=aux[0], page_size=aux[1])

    def replace(self, **kw) -> "PagedKVCache":
        args = {"buffers": self.buffers, "page_table": self.page_table,
                "seq_lens": self.seq_lens, "layout": self.layout,
                "page_size": self.page_size}
        args.update(kw)
        return PagedKVCache(args["buffers"], args["page_table"],
                            args["seq_lens"], layout=args["layout"],
                            page_size=args["page_size"])

    # ------------------------------------------------------------ shapes
    @property
    def n_pages(self) -> int:       # physical pool size
        return self.buffers[0].shape[1]

    @property
    def n_pages_max(self) -> int:   # logical pages per request
        return self.page_table.shape[1]

    @property
    def max_context(self) -> int:
        return self.n_pages_max * self.page_size

    @property
    def batch(self) -> int:
        return self.page_table.shape[0]

    @classmethod
    def init(cls, cfg: ModelConfig, batch: int, max_len: int, *,
             page_size: int, layout: str = "split", n_pages: int = 0,
             dtype=None) -> "PagedKVCache":
        """Fresh pool.  ``page_table`` starts with every request's
        pages linearly pre-assigned (request ``b`` owns pages
        ``1 + b*n .. 1 + (b+1)*n - 1``); continuous batching rewrites
        rows through :meth:`assign_pages` as requests come and go."""
        if cfg.sliding_window is not None:
            raise NotImplementedError(
                "paged decode has no ring semantics; sliding-window "
                f"config {cfg.name} needs the dense cache")
        dt = dtype or jnp.dtype(cfg.dtype)
        nl, hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        npm = -(-max_len // page_size)
        pool = max(n_pages, 1 + batch * npm)   # + reserved page 0
        if layout == "fused":
            buffers = (jnp.zeros((nl, pool, page_size, 2 * hkv, dh), dt),)
        else:
            buffers = (jnp.zeros((nl, pool, page_size, hkv, dh), dt),
                       jnp.zeros((nl, pool, page_size, hkv, dh), dt))
        table = 1 + jnp.arange(batch * npm, dtype=jnp.int32
                               ).reshape(batch, npm)
        return cls(buffers, table, jnp.zeros((batch,), jnp.int32),
                   layout=layout, page_size=page_size)

    # ------------------------------------------------- slot bookkeeping
    def assign_pages(self, slot: int, pages, length: int
                     ) -> "PagedKVCache":
        """Point request ``slot`` at ``pages`` (list padded with 0)
        with ``length`` live tokens (continuous-batching admit/evict)."""
        row = jnp.zeros((self.n_pages_max,), jnp.int32)
        row = row.at[:len(pages)].set(jnp.asarray(pages, jnp.int32))
        return self.replace(
            page_table=self.page_table.at[slot].set(row),
            seq_lens=self.seq_lens.at[slot].set(jnp.int32(length)))

    def write_tokens(self, slot: int, k, v, start: int
                     ) -> "PagedKVCache":
        """Scatter prefilled K/V (``(L, Hkv, S, dh)``) for request
        ``slot`` at positions ``start..start+S-1`` (admit path: the
        dense prefill cache lands in this slot's pages)."""
        s = k.shape[2]
        pos = start + jnp.arange(s)
        flat = self.page_table[slot, pos // self.page_size] \
            * self.page_size + pos % self.page_size
        buffers = list(self.buffers)
        if self.layout == "fused":
            nl, hkv, dh = k.shape[0], k.shape[1], k.shape[3]
            kv = jnp.stack([k, v], axis=2)          # (L, Hkv, 2, S, dh)
            kv = kv.reshape(nl, 2 * hkv, s, dh)     # head-interleaved
            kv = kv.transpose(0, 2, 1, 3)           # (L, S, 2Hkv, dh)
            fl = _flat(self.buffers[0])
            buffers[0] = fl.at[:, flat].set(kv.astype(fl.dtype)
                                            ).reshape(self.buffers[0].shape)
        else:
            for i, t in enumerate((k, v)):
                fl = _flat(self.buffers[i])
                buffers[i] = fl.at[:, flat].set(
                    t.transpose(0, 2, 1, 3).astype(fl.dtype)
                ).reshape(self.buffers[i].shape)
        return self.replace(buffers=tuple(buffers))

    def gather_dense(self, li: int) -> Tuple[jax.Array, jax.Array]:
        """Dense ``(B, Hkv, Cmax, dh)`` K and V views of layer ``li``
        (logical order; positions past ``seq_lens`` are whatever the
        mapped page holds and must be masked by the caller)."""
        pools = tuple(buf[li] for buf in self.buffers)
        return _gather_layer(pools, self.page_table, self.layout,
                             self.page_size)


def _flat(buf: jax.Array) -> jax.Array:
    """Pages flattened to one token axis: ``(..., P*ps, H, dh)``."""
    *lead, p, ps, h, dh = buf.shape
    return buf.reshape(*lead, p * ps, h, dh)


def _append_layer(pools, page_table, seq_lens, k, v, layout: str,
                  page_size: int) -> Tuple[jax.Array, ...]:
    """One layer's pools (each ``(P, ps, H, dh)``) with the token K/V
    (``(B, Hkv, dh)``) scattered at each request's ``seq_lens`` slot."""
    batch = page_table.shape[0]
    idx = page_table[jnp.arange(batch), seq_lens // page_size] \
        * page_size + seq_lens % page_size
    if layout == "fused":
        b_, hkv, dh = k.shape
        kv = jnp.stack([k, v], axis=2).reshape(b_, 2 * hkv, dh)
        fl = _flat(pools[0])
        return (fl.at[idx].set(kv.astype(fl.dtype)
                               ).reshape(pools[0].shape),)
    out = []
    for pool, t in zip(pools, (k, v)):
        fl = _flat(pool)
        out.append(fl.at[idx].set(t.astype(fl.dtype)
                                  ).reshape(pool.shape))
    return tuple(out)


def _gather_layer(pools, page_table, layout: str, page_size: int
                  ) -> Tuple[jax.Array, jax.Array]:
    """Dense ``(B, Hkv, Cmax, dh)`` K/V views of one layer's pools."""
    npm = page_table.shape[1]
    cmax = npm * page_size
    pos = jnp.arange(cmax)
    gidx = page_table[:, pos // page_size] * page_size \
        + pos % page_size                                # (B, Cmax)
    if layout == "fused":
        g = _flat(pools[0])[gidx]                        # (B, Cmax, 2H, dh)
        b_, _, h2, dh = g.shape
        g = g.reshape(b_, cmax, h2 // 2, 2, dh)
        ck, cv = g[..., 0, :], g[..., 1, :]
    else:
        ck = _flat(pools[0])[gidx]
        cv = _flat(pools[1])[gidx]
    return (ck.transpose(0, 2, 1, 3), cv.transpose(0, 2, 1, 3))


# -------------------------------------------------------------- decode
def _paged_attn(p, x, cfg: ModelConfig, pools, page_table, seq_lens,
                layout: str, page_size: int, use_pallas: bool):
    """One layer's decode attention over its page pools; the math and
    casts of ``transformer._attn``'s decode branch with per-request
    positions.  Returns ``(attn_out, new_pools)``."""
    b, s, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"])
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    positions = seq_lens[:, None]                        # (B, 1)
    q = L.rope(q.reshape(b, s, hq, dh), positions, cfg.rope_theta)
    k = L.rope(k.reshape(b, s, hkv, dh), positions, cfg.rope_theta)
    v = v.reshape(b, s, hkv, dh)
    group = hq // hkv
    qg = q.reshape(b, s, hkv, group, dh)
    k1, v1 = k[:, 0], v[:, 0]                            # (B, Hkv, dh)

    if use_pallas:
        from repro.core.codegen_pallas import lower_paged_decode
        kern = lower_paged_decode(
            batch=b, kv_heads=hkv, group=group, head_dim=dh,
            page_size=page_size, n_pages_max=page_table.shape[1],
            layout=layout)
        out, new_pools = kern(qg[:, 0], k1, v1, pools,
                              page_table, seq_lens)
        out = out[:, None]                               # (B, 1, Hkv, g, dh)
    else:
        new_pools = _append_layer(pools, page_table, seq_lens, k1, v1,
                                  layout, page_size)
        ck, cv = _gather_layer(new_pools, page_table, layout,
                               page_size)                # (B,Hkv,Cmax,dh)
        scores = jnp.einsum("bskgh,bkch->bskgc",
                            qg.astype(jnp.float32),
                            ck.astype(jnp.float32)) * dh ** -0.5
        slotpos = jnp.arange(ck.shape[2])
        valid = slotpos[None, :] <= seq_lens[:, None]    # (B, Cmax)
        scores = jnp.where(valid[:, None, None, None, :],
                           scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bskgc,bkch->bskgh", probs,
                         cv.astype(jnp.float32))
    out = out.reshape(b, s, hq * dh).astype(x.dtype)
    return jnp.einsum("bsq,qd->bsd", out, p["wo"]), tuple(new_pools)


def paged_decode_step(params: Params, cfg: ModelConfig,
                      cache: PagedKVCache, tokens: jax.Array, *,
                      use_pallas: bool = False):
    """One decode step for every active request: tokens ``(B, 1)``,
    per-request positions from ``cache.seq_lens``.  Returns
    ``(logits, cache')`` with every request's length advanced by one.
    Dense/MoE attention families only (recurrent families have no KV
    cache to page).  Structured exactly like ``model.decode_step``
    (same layer scan over the same stacked params) so the two paths
    stay bit-comparable."""
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"paged decode supports dense/moe, not {cfg.family}")
    x = _embed_tokens(params, cfg, tokens)
    attn, dense, moe = _layer_stacks(params, cfg)
    period = cfg.moe_layer_period if cfg.n_experts else 1
    n_super = cfg.n_layers // period
    table, lens = cache.page_table, cache.seq_lens
    layout, ps = cache.layout, cache.page_size

    def super_block(carry, slices):
        x = carry
        a_slc, d_slc, m_slc, pools_slc = slices
        new_pools = [[] for _ in pools_slc]
        for i in range(period):
            is_moe = bool(moe) and i == period - 1
            sl = {k: v[i] for k, v in a_slc.items()}
            if is_moe:
                sl.update(m_slc)
            else:
                sl.update({k: v[i] for k, v in d_slc.items()})
            layer_pools = tuple(pp[i] for pp in pools_slc)
            a, lp = _paged_attn(sl, L.rms_norm(x, sl["ln1"]), cfg,
                                layer_pools, table, lens, layout, ps,
                                use_pallas)
            x = x + a
            h = L.rms_norm(x, sl["ln2"])
            if is_moe:
                moe_p = {k[4:]: v for k, v in sl.items()
                         if k.startswith("moe_")}
                x = x + moe_mod.moe_ffn(moe_p, h, cfg)
            else:
                x = x + _dense_ffn(sl, h, cfg)
            for j, npool in enumerate(lp):
                new_pools[j].append(npool)
        return x, tuple(jnp.stack(nps) for nps in new_pools)

    def stack_reshape(t):
        return t.reshape((n_super, period) + t.shape[1:])

    a_stk = jax.tree.map(stack_reshape, attn)
    if dense and moe:
        d_stk = jax.tree.map(
            lambda t: t.reshape((n_super, period - 1) + t.shape[1:]),
            dense)
    else:
        d_stk = jax.tree.map(stack_reshape, dense) if dense else {}
    pools_stk = tuple(stack_reshape(buf) for buf in cache.buffers)

    x, new_stk = L.scan_layers(super_block, x,
                               (a_stk, d_stk, moe, pools_stk),
                               cfg.unroll)
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    buffers = tuple(nb.reshape(buf.shape)
                    for nb, buf in zip(new_stk, cache.buffers))
    return logits, cache.replace(buffers=buffers, seq_lens=lens + 1)
