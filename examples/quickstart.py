"""Quickstart: the paper's pipeline end to end on matrix multiply.

    PYTHONPATH=src python examples/quickstart.py

1. write GEMM as nested parallel patterns (Map of MultiFold);
2. tile it (strip-mine -> stage lifting -> interchange -> tile copies);
3. inspect the cost model (Fig. 5c-style traffic table) and the
   metapipeline schedule (Fig. 6-style stages);
4. execute the jnp lowering AND the generated Pallas kernel; compare.
"""
import numpy as np

from repro.core import describe, execute, tile
from repro.core.codegen_pallas import lower
from repro.core.cost import traffic
from repro.core.memory import plan_memory
from repro.core.scheduling import build_schedule
from repro.patterns.analytics import gemm

pattern, sizes, make_inputs, reference = gemm(m=128, n=128, k=128,
                                              bm=64, bn=64, bk=64)
print("== original PPL program ==")
print(describe(pattern))

tiled = tile(pattern, sizes)
print("\n== tiled (strip-mined + interchanged + tile copies) ==")
print(describe(tiled))

print("\n== main-memory traffic (words) ==")
base_t, tiled_t = traffic(pattern), traffic(tiled)
for name in base_t.reads:
    print(f"  {name}: base={base_t.reads[name]} "
          f"tiled={tiled_t.reads[name]} "
          f"({base_t.reads[name] / tiled_t.reads[name]:.1f}x fewer)")

print("\n== metapipeline schedule ==")
print(build_schedule(tiled).describe())

print("\n== memory plan (VMEM) ==")
print(plan_memory(tiled).describe())

print("\n== automated tile-size selection (the paper's future work) ==")
from repro.kernels.autotile import select_gemm_tiles
choice = select_gemm_tiles(512, 512, 512)
print(f"  DSE picks bm={choice.block_m} bn={choice.block_n} "
      f"bk={choice.block_k} (traffic {choice.traffic_words} words, "
      f"VMEM {choice.vmem_bytes} B)")

inputs = make_inputs()
ref = reference(inputs)
out_jnp = np.asarray(execute(tiled, inputs))
out_pallas = np.asarray(lower(tiled)(**inputs))
print("\njnp lowering max err:   ", np.abs(out_jnp - ref).max())
print("pallas kernel max err:  ", np.abs(out_pallas - ref).max())
print("OK")
