"""Public jit'd wrappers for the Pallas kernels.

Every op takes ``use_pallas``: True -> the Pallas kernel (interpret mode
on CPU, compiled on TPU); False -> the jnp oracle (used by the 512-device
dry-run, where interpret-mode kernels would be pure overhead).  Both
paths are numerically validated against each other in tests/.

``resolve_plan`` is the shared auto-tile front door: every kernel's
``auto_tile=True`` path resolves its DSE plan here (one memo, one
selector table) instead of carrying a private ``_auto_blocks`` copy.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import filter_reduce as _fr
from . import flash_attention as _fa
from . import groupby_fold as _gbf
from . import matmul as _mm
from . import ref
from . import ssd_scan as _ssd

# pattern-domain kind -> core.dse selector; every selector returns
# (blocks, plan) where ``blocks`` is whatever tile tuple/scalar the
# kernel's pallas_call consumes
_SELECTORS = {
    "gemm": "select_gemm_blocks",
    "attention": "select_attention_blocks",
    "scan": "select_scan_blocks",
    "filter_reduce": "select_filter_reduce_blocks",
    "groupby": "select_groupby_blocks",
    "fused_filter_fold": "select_fused_filter_fold_blocks",
    "fused_kmeans": "select_fused_kmeans_blocks",
    "paged_decode": "select_paged_decode_blocks",
}

_PLAN_MEMO: dict = {}


def resolve_plan(kind: str, *shape: int, measure: Optional[str] = None,
                 policy=None, options=None):
    """Resolve the DSE tile plan for ``kind`` at ``shape``.

    Returns the selector's ``(blocks, plan)``: ``blocks`` is the tile
    tuple (or scalar) the kernel consumes, ``plan`` the full
    ``TilePlan`` / ``PipelinePlan``.  Results are memoized in-process
    (the on-disk TuningCache already dedupes across processes, but the
    memo also skips proxy-program construction and cache IO on the hot
    serving path).  Plans adapted from a shape bucket
    (``plan.warm_start``) are *not* memoized: once the background
    re-tune promotes the exact-shape winner, the next resolve picks it
    up from the cache.
    """
    from repro.core import dse, telemetry

    if kind not in _SELECTORS:
        raise ValueError(f"unknown plan kind {kind!r}; "
                         f"one of {sorted(_SELECTORS)}")
    key = None
    try:
        key = (kind, shape, measure, policy, options)
        hit = _PLAN_MEMO.get(key)
    except TypeError:      # unhashable policy/options: skip the memo
        key = None         # the tuple itself bound fine; only .get raised
        hit = None
    if hit is not None:
        telemetry.count("ops.memo_hits")
        return hit
    with telemetry.span("ops.resolve_plan", kind=kind,
                        shape=list(shape)) as sp:
        result = getattr(dse, _SELECTORS[kind])(*shape, measure=measure,
                                                policy=policy,
                                                options=options)
        sp.set(warm_start=bool(getattr(result[1], "warm_start", False)))
    if key is not None and not getattr(result[1], "warm_start", False):
        _PLAN_MEMO[key] = result
    return result


def clear_plan_memo() -> None:
    """Drop the in-process plan memo (tests; cache path changes)."""
    _PLAN_MEMO.clear()


@functools.partial(jax.jit, static_argnames=("use_pallas", "block_m",
                                             "block_n", "block_k"))
def matmul(x, y, *, use_pallas: bool = True, block_m: int = 128,
           block_n: int = 128, block_k: int = 128):
    if use_pallas:
        return _mm.matmul(x, y, block_m=block_m, block_n=block_n,
                          block_k=block_k)
    return ref.matmul(x, y).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "use_pallas", "block_q",
                                             "block_k"))
def attention(q, k, v, *, causal: bool = True,
              window: Optional[int] = None, use_pallas: bool = True,
              block_q: int = 128, block_k: int = 128):
    if use_pallas:
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k)
    return ref.attention(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def ssd(x, dt, A, B, C, *, chunk: int = 128, use_pallas: bool = True):
    if use_pallas:
        return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk)
    return ref.ssd_scan(x, dt, A, B, C)


@functools.partial(jax.jit, static_argnames=("num_keys", "use_pallas",
                                             "block_t"))
def groupby(keys, values, num_keys: int, *, use_pallas: bool = True,
            block_t: int = 256):
    if use_pallas:
        return _gbf.groupby_fold(keys, values, num_keys, block_t=block_t)
    return ref.groupby_fold(keys, values, num_keys)


@functools.partial(jax.jit, static_argnames=("use_pallas", "block_t"))
def filter_sum(x, weight, lo, hi, *, use_pallas: bool = True,
               block_t: int = 1024):
    if use_pallas:
        return _fr.filter_reduce(x, weight, lo, hi, block_t=block_t)
    return ref.filter_reduce(x, jnp.float32(lo), jnp.float32(hi), weight)
