"""Strip mining: Table 1 of the paper, plus the second pass that turns
statically-predictable accesses into explicit tile copies.

Pass 1 (``strip_mine``) splits each named pattern's domain ``d`` into a
perfectly nested pair: a *strided* outer pattern over ``d/b`` and an
inner pattern over a tile of size ``b``:

    T[ Map(d)(m) ]          = MultiFold(d/b)(d)(zeros(d))
                                { i => (i*b, acc => Map(b)(T[m])) } (_)
    T[ MultiFold(d)(r)(z)(g)(c) ]
                            = MultiFold(d/b)(r)(z)
                                { i => (i', acc => c(acc, MultiFold(b)(r')(z')(T[g])(c))) }(c)
    T[ GroupByFold(d)(z)(h)(c) ]
                            = GroupByFold(d/b)(z){ i => GroupByFold(b)(z)(T[h])(c) }(c)
    T[ FlatMap(d)(f) ]      = FlatMap(d/b){ i => FlatMap(b)(T[f]) }

Pass 2 (``insert_tile_copies``) probes every affine access, splits its
index dependences into *strided* (grid) and *local* dims, and hoists an
explicit ``TileCopy`` to the deepest pattern binding all strided dims it
needs -- the paper's "second strip mining pass" plus the code-motion/CSE
cleanup it assumes.  Non-affine accesses are left in place (they become
cache-backed gathers during hardware generation, not tiling failures).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import ir, rewrite
from .affine import AffineMap, touched_extent

# --------------------------------------------------------------------------
# Pass 1: domain splitting (Table 1)
# --------------------------------------------------------------------------


def _tile_tuple(domain: Tuple[int, ...], sizes) -> Tuple[int, ...]:
    """Resolve per-dim tile sizes; None -> untiled (b = extent)."""
    if sizes is None:
        return tuple(domain)
    if isinstance(sizes, int):
        sizes = (sizes,) + (None,) * (len(domain) - 1)
    assert len(sizes) == len(domain), (sizes, domain)
    out = []
    for d, b in zip(domain, sizes):
        b = d if b is None else b
        assert d % b == 0, (
            f"tile {b} must divide extent {d} (ragged tiles: future min-check)")
        out.append(b)
    return tuple(out)


def _grid_local_xform(enc: int, k: int, tiles: Tuple[int, ...]):
    """Stack transform: callables written against (enc, i_1..i_k) now
    receive (enc, g_1..g_k, l_1..l_k); recover i = g*b + l."""

    def edit(head):
        e = head[:enc]
        g = head[enc:enc + k]
        l = head[enc + k:enc + 2 * k]
        return tuple(e) + tuple(gi * b + li for gi, b, li in zip(g, tiles, l))

    return rewrite.prefix_preserving_tail(edit, enc + 2 * k)


def _recurse_children(p: ir.Pattern, sizes: Dict[str, Sequence],
                      enc: int) -> ir.Pattern:
    """Strip-mine descendants (T[m] recursion) before wrapping ``p``."""
    updates = {}
    if p.inner is not None:
        updates["inner"] = _strip_mine(p.inner, sizes, enc + len(p.domain))
    new_reads, changed = [], False
    for a in p.accesses:
        if isinstance(a.src, ir.Pattern):
            # pattern sources are evaluated with the consumer's full stack
            new_src = _strip_mine(a.src, sizes, enc + len(p.domain))
            if new_src is not a.src:
                a = dataclasses.replace(a, src=new_src)
                changed = True
        new_reads.append(a)
    if changed:
        updates["reads"] = tuple(new_reads)
    return dataclasses.replace(p, **updates) if updates else p


def _strip_mine(p: ir.Pattern, sizes: Dict[str, Sequence],
                enc: int) -> ir.Pattern:
    p = _recurse_children(p, sizes, enc)
    if p.name not in sizes or p.strided:
        return p
    tiles = _tile_tuple(p.domain, sizes[p.name])
    k = len(p.domain)
    grid = tuple(d // b for d, b in zip(p.domain, tiles))
    xform = _grid_local_xform(enc, k, tiles)
    dtype = jnp.dtype(p.dtype)

    if isinstance(p, ir.Map):
        inner = ir.Map(
            domain=tiles, elem_shape=p.elem_shape,
            reads=tuple(rewrite._rewrap_access(a, xform) for a in p.reads),
            fn=rewrite.wrap_body_fn(p.fn, xform) if p.fn else None,
            inner=rewrite.rewrap(p.inner, xform) if p.inner else None,
            name=p.name + "_tile", dtype=p.dtype)
        out_shape = tuple(p.domain) + tuple(p.elem_shape)
        n_elem = len(p.elem_shape)

        def out_map(*stack):
            g = stack[enc:enc + k]
            return tuple(gi * b for gi, b in zip(g, tiles)) + (0,) * n_elem

        return ir.MultiFold(
            domain=grid, range_shape=out_shape,
            init=lambda: jnp.zeros(out_shape, dtype),
            out_index_map=out_map,
            update_shape=tuple(tiles) + tuple(p.elem_shape),
            combine=None,  # write-once: the paper's "(_)"
            inner=inner, strided=True, name=p.name, dtype=p.dtype)

    if isinstance(p, ir.MultiFold):
        # probe the output map: strides of acc location w.r.t. own dims
        amap = AffineMap.probe(p.out_index_map, enc + k)
        own_cols = [amap.col(enc + j) for j in range(k)]
        touched = touched_extent(own_cols, tiles, p.update_shape)
        z_full = np.asarray(p.init())

        def inner_init(_z=z_full, _t=touched):
            # uniform-identity slice of z (z must be combine's identity)
            sl = tuple(slice(0, t) for t in _t)
            return jnp.asarray(_z[sl])

        def inner_out_map(*stack):
            # relative to the tile's touched-region base
            l = stack[enc + k:enc + 2 * k]
            rel = [0] * amap.n_out
            for j, li in enumerate(l):
                for d_, s in enumerate(own_cols[j]):
                    rel[d_] += s * li
            return tuple(rel)

        inner = ir.MultiFold(
            domain=tiles, range_shape=touched, init=inner_init,
            reads=tuple(rewrite._rewrap_access(a, xform) for a in p.reads),
            out_index_map=inner_out_map, update_shape=tuple(p.update_shape),
            fn=rewrite.wrap_body_fn(p.fn, xform) if p.fn else None,
            combine=p.combine,
            inner=rewrite.rewrap(p.inner, xform) if p.inner else None,
            name=p.name + "_tile", dtype=p.dtype)

        def outer_out_map(*stack):
            e, g = stack[:enc], stack[enc:enc + k]
            return amap(*(tuple(e) + tuple(gi * b for gi, b in zip(g, tiles))))

        return ir.MultiFold(
            domain=grid, range_shape=tuple(p.range_shape), init=p.init,
            out_index_map=outer_out_map, update_shape=touched,
            combine=p.combine, inner=inner, strided=True,
            name=p.name, dtype=p.dtype)

    if isinstance(p, ir.GroupByFold):
        assert k == 1, "GroupByFold has a 1-D domain"
        inner = ir.GroupByFold(
            domain=tiles, num_keys=p.num_keys, elem_shape=p.elem_shape,
            init=p.init,
            reads=tuple(rewrite._rewrap_access(a, xform) for a in p.reads),
            fn=rewrite.wrap_body_fn(p.fn, xform) if p.fn else None,
            combine=p.combine,
            inner=rewrite.rewrap(p.inner, xform) if p.inner else None,
            name=p.name + "_tile", dtype=p.dtype)
        return ir.GroupByFold(
            domain=grid, num_keys=p.num_keys, elem_shape=p.elem_shape,
            init=p.init, combine=p.combine, inner=inner, strided=True,
            name=p.name, dtype=p.dtype)

    if isinstance(p, ir.FlatMap):
        assert k == 1, "FlatMap has a 1-D domain"
        inner = ir.FlatMap(
            domain=tiles, max_per_iter=p.max_per_iter,
            elem_shape=p.elem_shape,
            reads=tuple(rewrite._rewrap_access(a, xform) for a in p.reads),
            fn=rewrite.wrap_body_fn(p.fn, xform) if p.fn else None,
            inner=rewrite.rewrap(p.inner, xform) if p.inner else None,
            name=p.name + "_tile", dtype=p.dtype)
        return ir.FlatMap(
            domain=grid, max_per_iter=tiles[0] * p.max_per_iter,
            elem_shape=p.elem_shape, inner=inner, strided=True,
            name=p.name, dtype=p.dtype)

    raise TypeError(type(p))


def strip_mine(p: ir.Pattern, sizes: Dict[str, Sequence]) -> ir.Pattern:
    """Strip-mine every pattern whose ``name`` appears in ``sizes``.

    ``sizes[name]`` is a per-dim tuple of tile sizes (None = untiled dim).
    """
    return _strip_mine(p, sizes, enc=0)


# --------------------------------------------------------------------------
# Pass 2: tile-copy insertion with code motion + CSE
# --------------------------------------------------------------------------


@dataclasses.dataclass
class _Level:
    """One pattern level on the current path."""

    pattern: ir.Pattern
    offset: int          # stack offset of this pattern's indices
    rank: int
    strided: bool


class _CopyCtx:
    def __init__(self, vmem_budget_words: int):
        self.budget = vmem_budget_words
        # (level_id, src_name, sig) -> TileCopy, for CSE
        self.memo: Dict[Tuple, ir.TileCopy] = {}
        # level object id -> list of TileCopy to attach
        self.pending: Dict[int, List[ir.TileCopy]] = {}


def _strided_dims(levels: List[_Level]) -> List[int]:
    dims = []
    for lv in levels:
        if lv.strided:
            dims.extend(range(lv.offset, lv.offset + lv.rank))
    return dims


def _make_copy(ctx: _CopyCtx, levels: List[_Level], a: ir.Access
               ) -> Optional[ir.Access]:
    """Try to convert access ``a`` (owned by levels[-1]) into a tile copy.

    The copy attaches at the deepest level binding a *strided* dim the
    access depends on (code motion).  Dims bound at or above the attach
    level contribute to the copy's base index map; dims bound below are
    covered by the copy's extent.  A copy whose base is constant is
    marked ``hoisted`` (loop-invariant: the Pipe-0 preload of Fig. 6).
    """
    if not a.affine or not isinstance(a.src, ir.Tensor):
        return None
    stack_len = levels[-1].offset + levels[-1].rank
    amap = AffineMap.probe(a.index_map, stack_len)
    deps = set(amap.dependent_dims())
    strided = set(d for d in _strided_dims(levels) if d < stack_len)
    sdeps = sorted(deps & strided)

    attach = 0
    if sdeps:
        for li, lv in enumerate(levels):
            if lv.offset <= sdeps[-1] < lv.offset + lv.rank:
                attach = li
    attach_lv = levels[attach]
    attach_stack = attach_lv.offset + attach_lv.rank

    # dims below the attach level are covered by the copy's extent
    below = sorted(d for d in deps if d >= attach_stack)
    ext_sizes, ext_cols = [], []
    for d in below:
        for lv in levels:
            if lv.offset <= d < lv.offset + lv.rank:
                ext_sizes.append(lv.pattern.domain[d - lv.offset])
        ext_cols.append(amap.col(d))
    tile_shape = touched_extent(ext_cols, ext_sizes, a.window)
    if int(np.prod(tile_shape)) > ctx.budget:
        return None  # stream it: tile would not fit on chip

    # copy base: columns of dims bound at/above attach; zero elsewhere
    copy_mat = tuple(
        tuple(amap.col(d_in)[d_out] if d_in < attach_stack else 0
              for d_in in range(attach_stack))
        for d_out in range(amap.n_out))
    copy_map = AffineMap(amap.base, copy_mat, arity=attach_stack)
    hoisted = all(all(m == 0 for m in row) for row in copy_mat)

    sig = (id(a.src), copy_map.base, copy_map.mat, tile_shape)
    key = (id(attach_lv.pattern), sig)
    if key in ctx.memo:
        tc = ctx.memo[key]
    else:
        tc = ir.TileCopy(src=a.src, index_map=copy_map,
                         tile_shape=tile_shape, hoisted=hoisted,
                         name=f"{a.src.name}_tile")
        ctx.memo[key] = tc
        ctx.pending.setdefault(id(attach_lv.pattern), []).append(tc)

    # rewritten access: below-attach dims only, relative to the tile base
    local_mat = tuple(
        tuple(amap.col(d_in)[d_out] if d_in in below else 0
              for d_in in range(stack_len))
        for d_out in range(amap.n_out))
    local_map = AffineMap((0,) * amap.n_out, local_mat, arity=stack_len)
    return dataclasses.replace(a, src=tc, index_map=local_map)


def _insert_copies(p: ir.Pattern, levels: List[_Level],
                   ctx: _CopyCtx) -> ir.Pattern:
    me = _Level(p, offset=(levels[-1].offset + levels[-1].rank) if levels
                else 0, rank=len(p.domain), strided=p.strided)
    path = levels + [me]

    new_reads = []
    for a in p.accesses:
        res = _make_copy(ctx, path, a)
        if res is not None:
            new_reads.append(res)
        elif isinstance(a.src, ir.Pattern):
            # pattern sources are evaluated with the consumer's full stack
            new_reads.append(dataclasses.replace(
                a, src=_insert_copies(a.src, path, ctx)))
        else:
            new_reads.append(a)
    updates: Dict = {"reads": tuple(new_reads)}

    # pattern-valued tile loads (lifted stages) are evaluated at this
    # level: recurse BEFORE collecting copies attached here
    new_loads = []
    for tc in p.loads:
        if isinstance(tc.src, ir.Pattern):
            tc = dataclasses.replace(tc, src=_insert_copies(tc.src, path, ctx))
        new_loads.append(tc)

    if p.inner is not None:
        updates["inner"] = _insert_copies(p.inner, path, ctx)

    mine = ctx.pending.pop(id(p), [])
    updates["tile_loads"] = tuple(new_loads) + tuple(mine)
    return dataclasses.replace(p, **updates)


def insert_tile_copies(p: ir.Pattern, *,
                       vmem_budget_words: int = 4 * 1024 * 1024
                       ) -> ir.Pattern:
    """Pass 2: explicit tile copies for statically-predictable accesses.

    Copies requested by descendants get attached to the ancestor pattern
    whose strided indices they depend on (code motion) and identical
    copies are merged (CSE).  Default budget: 16 MiB VMEM / 4 B words.
    """
    ctx = _CopyCtx(vmem_budget_words)
    out = _insert_copies(p, [], ctx)
    assert not ctx.pending, "unattached tile copies (hoist level bug)"
    return out


def tile(p: ir.Pattern, sizes: Dict[str, Sequence], *,
         apply_interchange: bool = True,
         vmem_budget_words: int = 4 * 1024 * 1024) -> ir.Pattern:
    """Full tiling pipeline (paper Fig. 1 "high level transformations"):
    strip-mine -> lift tile stages (split heuristic) -> interchange ->
    insert tile copies (code motion + CSE)."""
    from .fusion import lift_tile_stages  # local imports: avoid cycles
    from .interchange import interchange as _interchange
    out = strip_mine(p, sizes)
    if apply_interchange:
        out = lift_tile_stages(out, vmem_budget_words=vmem_budget_words)
        out = _interchange(out, vmem_budget_words=vmem_budget_words)
    return insert_tile_copies(out, vmem_budget_words=vmem_budget_words)
