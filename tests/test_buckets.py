"""Shape buckets + warm-start re-tuning + the unified Options surface.

Covers the ISSUE-8 acceptance points: kwarg > options > env > default
precedence (and legacy-kwarg call sites producing plans identical to
``options=Options(...)``), bucket-key round-trip through the tuning
cache, nearest-bucket warm start with *zero foreground lowering*,
certified-only background promotion, and numerical equivalence of a
warm-started (padded-to-bucket) kernel against the exact-shape oracle.
"""
import numpy as np
import pytest

from repro.core import buckets, dse, resilience
from repro.core.dse import TuningCache
from repro.core.options import DEPTHS, MAX_POINTS, UNSET, Options
from repro.core.cost import VMEM_BYTES


# ------------------------------------------------------------ Options
def test_options_defaults_resolved():
    o = Options().resolved()
    assert o.vmem_budget == VMEM_BYTES
    assert o.max_points == MAX_POINTS
    assert o.depths == DEPTHS
    assert o.measure is None
    assert o.bucketing is False


def test_options_precedence_kwarg_options_env_default(monkeypatch):
    """Explicit kwarg > options=Options(...) > env > built-in default,
    per field."""
    # env beats default
    monkeypatch.setenv("REPRO_BUCKETING", "1")
    monkeypatch.setenv("REPRO_DSE_CACHE", "/tmp/env-cache.json")
    o = dse._resolve_options(None)
    assert o.bucketing is True
    assert o.cache == "/tmp/env-cache.json"
    # options beats env (cache=False is a *set* value, not "unset")
    o = dse._resolve_options(Options(cache=False, bucketing=False))
    assert o.cache is False
    assert o.bucketing is False
    # kwarg beats options -- including falsy explicit values
    o = dse._resolve_options(Options(max_points=99, bucketing=True),
                             max_points=7, bucketing=False)
    assert o.max_points == 7
    assert o.bucketing is False
    # a None-valued kwarg is "not passed", not an override
    o = dse._resolve_options(Options(measure="top_k"), measure=None)
    assert o.measure == "top_k"


def test_options_from_env_is_the_single_env_reader(monkeypatch):
    for var in ("REPRO_MEASURE", "REPRO_DSE_CACHE", "REPRO_TIMING_DB",
                "REPRO_BUCKETING", "REPRO_TIMEOUT_S"):
        monkeypatch.delenv(var, raising=False)
    o = Options.from_env()
    assert all(getattr(o, f) is UNSET
               for f in ("measure", "cache", "timing_db", "bucketing",
                         "policy"))
    monkeypatch.setenv("REPRO_MEASURE", "top_k")
    monkeypatch.setenv("REPRO_BUCKETING", "yes")
    monkeypatch.setenv("REPRO_TIMEOUT_S", "9")
    o = Options.from_env()
    assert o.measure == "top_k"
    assert o.bucketing is True
    assert o.policy.timeout_s == 9.0


def test_no_env_reads_outside_options_from_env():
    """Acceptance: no kernel (or the codegen layer) consults a REPRO_*
    env var directly -- the tuning env surface is Options.from_env()."""
    import pathlib

    import repro.core.codegen_pallas as cg
    import repro.kernels as kpkg

    files = list(pathlib.Path(kpkg.__path__[0]).glob("*.py"))
    files.append(pathlib.Path(cg.__file__))
    for f in files:
        src = f.read_text()
        assert "environ" not in src and "getenv" not in src, \
            f"{f.name} reads env vars directly; route through Options"


def test_legacy_kwargs_and_options_produce_identical_plans(tmp_path):
    p = dse.gemm_program(256, 256, 256)
    kw = dict(vmem_budget=VMEM_BYTES // 2, max_points=512,
              depths=(2, 3))
    a = dse.explore(p, cache=False, **kw)
    b = dse.explore(p, options=Options(cache=False, **kw))
    assert a.sizes == b.sizes
    assert a.depths == b.depths
    assert a.traffic_words == b.traffic_words


# ------------------------------------------------------------ bucket ladder
def test_bucket_extent_ladder():
    # {s*2^j, s*3*2^(j-1)}: powers of two plus their 1.5x midpoints
    assert [buckets.bucket_extent(n, sublane=8)
            for n in (1, 8, 9, 24, 25, 100, 128, 129, 200)] \
        == [8, 8, 16, 24, 32, 128, 128, 192, 256]
    # sublane floor: a bf16 bucket is never below 16 rows
    assert buckets.bucket_extent(3, sublane=16) == 16
    for n in range(1, 2000, 37):
        b = buckets.bucket_extent(n, sublane=8)
        assert b >= n and b % 8 == 0


def test_tile_family_ignores_extents():
    kw = dict(vmem_budget=VMEM_BYTES, align=128)
    f1 = buckets.tile_family(dse.gemm_program(256, 256, 256), **kw)
    f2 = buckets.tile_family(dse.gemm_program(120, 512, 384), **kw)
    f3 = buckets.tile_family(dse.attention_program(256, 256, 64), **kw)
    assert f1 == f2          # same pattern structure, any shape
    assert f1 != f3          # different pattern structure


# --------------------------------------------------- round-trip + warm start
def _tuned_cache(tmp_path, shape=(256, 256, 256)):
    """A TuningCache holding one tuned gemm donor (bucketing on)."""
    tc = TuningCache(path=str(tmp_path / "bucketed.json"))
    plan = dse.explore(dse.gemm_program(*shape),
                       options=Options(cache=tc, bucketing=True))
    buckets.drain()
    return tc, plan


def test_bucket_index_round_trips_through_cache(tmp_path):
    tc, plan = _tuned_cache(tmp_path)
    fam = buckets.tile_family(dse.gemm_program(256, 256, 256),
                              vmem_budget=VMEM_BYTES, align=128)
    entries = tc.bucket_entries(fam)
    assert len(entries) == 1
    (sig, entry), = entries.items()
    assert entry["kind"] == "tile"
    assert dse.TilePlan.from_json(entry["plan"]).sizes == plan.sizes
    # reload from disk: the index rides the persistent document
    tc2 = TuningCache(path=tc.path)
    assert tc2.bucket_entries(fam) == entries


def test_cold_shape_warm_starts_with_zero_foreground_lowering(
        tmp_path, monkeypatch):
    """A cold shape in a tuned bucket is served the donor's re-fitted
    plan immediately: no kernel lowering, no candidate enumeration --
    exactly one analytic pricing of the fitted plan."""
    tc, _ = _tuned_cache(tmp_path)
    from repro.core import codegen_pallas, measure

    def _boom(*a, **k):
        raise AssertionError("foreground lowering during warm start")

    monkeypatch.setattr(codegen_pallas, "lower_for_timing", _boom)
    monkeypatch.setattr(measure, "timed", _boom, raising=False)
    scheduled = []
    monkeypatch.setattr(buckets, "schedule_retune",
                        lambda tag, *a, **k: scheduled.append(tag))
    calls = []
    real_price = dse.price
    monkeypatch.setattr(
        dse, "price",
        lambda *a, **k: calls.append(1) or real_price(*a, **k))

    buckets.reset_stats()
    # 250 is not on the donor grid but buckets to 256
    warm = dse.explore(dse.gemm_program(250, 256, 256),
                       options=Options(cache=tc, bucketing=True))
    assert warm.warm_start
    assert warm.bucket == "gemm=256x256;gemm_k=256"
    assert len(calls) == 1                  # priced, never enumerated
    assert scheduled and scheduled[0].startswith("tile|")
    assert buckets.stats()["warm_hits"] == 1
    # the loaned plan is usable: divisor tiles of the cold shape
    for name, extents in (("gemm", (250, 256)), ("gemm_k", (256,))):
        for tile, extent in zip(warm.sizes[name], extents):
            assert extent % tile == 0


def test_background_retune_promotes_certified_winner(tmp_path):
    tc, _ = _tuned_cache(tmp_path)
    buckets.reset_stats()
    p = dse.gemm_program(250, 256, 256)
    warm = dse.explore(p, options=Options(cache=tc, bucketing=True))
    assert warm.warm_start
    buckets.drain()
    s = buckets.stats()
    assert s["retunes"] == 1 and s["promotions"] == 1
    assert s["retune_failures"] == 0
    # the promoted exact-shape winner is now a plain cache hit
    again = dse.explore(p, options=Options(cache=tc, bucketing=True))
    assert again.cached and not again.warm_start
    assert buckets.stats()["exact_hits"] == 1
    assert buckets.hit_rate() == 1.0


def test_uncertified_retune_is_discarded(tmp_path, monkeypatch):
    """A background winner that fails certification is never promoted:
    the cache keeps no entry for the exact shape and the failure is
    counted + recorded, not raised."""
    tc, _ = _tuned_cache(tmp_path)
    monkeypatch.setattr(
        resilience, "certify_tile_plan",
        lambda *a, **k: (False, "forced miscompare (test)"))
    buckets.reset_stats()
    resilience.LOG.reset()
    p = dse.gemm_program(250, 256, 256)
    warm = dse.explore(p, options=Options(cache=tc, bucketing=True))
    assert warm.warm_start
    buckets.drain()
    s = buckets.stats()
    assert s["promotions"] == 0 and s["retune_failures"] == 1
    # still only warm-startable -- no exact entry was written
    again = dse.explore(p, options=Options(cache=tc, bucketing=True))
    assert again.warm_start and not again.cached
    assert any(e.stage == "retune" for e in resilience.LOG.events())


def test_warm_start_plans_never_persist(tmp_path):
    tc, _ = _tuned_cache(tmp_path)
    warm = dse.explore(dse.gemm_program(250, 256, 256),
                       options=Options(cache=tc, bucketing=True))
    assert warm.warm_start
    js = warm.to_json()
    assert "warm_start" not in js and "bucket" not in js
    rt = dse.TilePlan.from_json(js)
    assert rt.warm_start is False and rt.bucket == ""
    buckets.drain()


def test_pipeline_bucket_warm_start_round_trip(tmp_path):
    tc = TuningCache(path=str(tmp_path / "pipe.json"))
    opts = Options(cache=tc, bucketing=True)
    donor = dse.explore_pipeline(dse.filter_fold_pipeline(4096),
                                 options=opts)
    buckets.drain()
    buckets.reset_stats()
    warm = dse.explore_pipeline(dse.filter_fold_pipeline(4000),
                                options=opts)
    assert warm.warm_start and warm.fused
    assert warm.depths == (donor.depths[0],)
    assert 4000 % warm.block == 0
    buckets.drain()
    assert buckets.stats()["promotions"] == 1


# ----------------------------------------------- numerical equivalence
def test_warm_started_kernel_matches_exact_oracle(tmp_path,
                                                  monkeypatch):
    """The kernel running under a warm-start plan (and its
    padded-to-bucket variant) computes the same numbers as the
    exact-shape oracle."""
    from repro.kernels import matmul as mm
    from repro.kernels import ops

    tc_path = str(tmp_path / "mm.json")
    monkeypatch.setenv("REPRO_DSE_CACHE", tc_path)
    opts = Options(bucketing=True)
    dse.explore(dse.gemm_program(256, 256, 256),
                options=Options(cache=tc_path, bucketing=True))
    buckets.drain()
    ops.clear_plan_memo()

    rng = np.random.RandomState(0)
    x = rng.randn(250, 256).astype(np.float32)
    y = rng.randn(256, 256).astype(np.float32)
    oracle = x @ y

    got = np.asarray(mm.matmul(x, y, auto_tile=True, options=opts))
    np.testing.assert_allclose(got, oracle, rtol=2e-5, atol=2e-5)

    # padded-to-bucket: run at the bucket extent, slice back
    xp = np.zeros((256, 256), np.float32)
    xp[:250] = x
    padded = np.asarray(mm.matmul(xp, y, auto_tile=True,
                                  options=opts))[:250]
    np.testing.assert_allclose(padded, oracle, rtol=2e-5, atol=2e-5)
    buckets.drain()


def test_resolve_plan_memoizes_but_not_warm_starts(tmp_path):
    from repro.kernels import ops

    tc_path = str(tmp_path / "memo.json")
    opts = Options(cache=tc_path, bucketing=True)
    dse.explore(dse.gemm_program(256, 256, 256), options=opts)
    buckets.drain()
    ops.clear_plan_memo()

    _, p1 = ops.resolve_plan("gemm", 250, 256, 256, options=opts)
    assert p1.warm_start
    buckets.drain()         # background promotion lands
    _, p2 = ops.resolve_plan("gemm", 250, 256, 256, options=opts)
    # not memoized while warm: the promoted exact plan is picked up
    assert not p2.warm_start and p2.cached
    _, p3 = ops.resolve_plan("gemm", 250, 256, 256, options=opts)
    assert p3 is p2          # steady state memoizes
