"""MusicGen-medium [arXiv:2306.05284; hf]: decoder over EnCodec tokens.

The EnCodec frontend is a STUB: input_specs() provides precomputed
4-codebook token frames; the model sums per-codebook embeddings and
emits 4 per-codebook heads (delay-pattern handling lives in the data
pipeline, not the backbone)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio", n_layers=48, d_model=1536,
    n_heads=24, n_kv_heads=24, head_dim=64, d_ff=6144, vocab=2048,
    activation="gelu", n_codebooks=4)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                     head_dim=16, d_ff=128, vocab=64, n_codebooks=2,
                     remat=False)
