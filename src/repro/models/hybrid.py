"""Zamba-2-style hybrid: Mamba-2 backbone + a *shared* attention block.

One transformer block's weights are reused at every ``shared_attn_every``
Mamba layers (arXiv:2411.15242): the weights are closed over by the scan
body (not scanned), which is exactly how parameter sharing stays compact
in the lowered HLO.  Each application keeps its own KV cache slot.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import ssm as ssm_mod
from . import transformer as tr
from .config import ModelConfig
from .sharding import hint

Params = Dict[str, Any]


def n_attn_apps(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_every


def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[Tuple[int, ...], str]]:
    d, f, v = cfg.d_model, cfg.d_ff, cfg.padded_vocab
    shapes: Dict[str, Tuple[Tuple[int, ...], str]] = {
        "embed": ((v, d), "embed"),
        "lm_head": ((d, v), "dense"),
        "final_norm": ((d,), "zeros"),
    }
    shapes.update(ssm_mod.block_param_shapes(cfg, cfg.n_layers, "m_"))
    # ONE shared attention + ffn block (leading dim 1 for uniformity)
    qk, kv = cfg.qk_dim, cfg.kv_dim
    shapes.update({
        "s_ln1": ((d,), "zeros"), "s_ln2": ((d,), "zeros"),
        "s_wq": ((d, qk), "dense"), "s_wk": ((d, kv), "dense"),
        "s_wv": ((d, kv), "dense"), "s_wo": ((qk, d), "dense"),
        "s_w1": ((d, f), "dense"), "s_w2": ((f, d), "dense"),
        "s_w3": ((d, f), "dense"),
    })
    return shapes


def _shared_slice(params: Params) -> Dict:
    return {k[2:]: v for k, v in params.items() if k.startswith("s_")}


def forward(params: Params, cfg: ModelConfig,
            tokens: jax.Array) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    b, s, d = x.shape
    positions = jnp.arange(s)
    shared = _shared_slice(params)
    every = cfg.shared_attn_every
    n_groups = cfg.n_layers // every
    m_stacks = {k: v for k, v in params.items() if k.startswith("m_")}

    def group(x, slices):
        for i in range(every):
            sl = {k: v[i] for k, v in slices.items()}
            x, _ = ssm_mod.block_forward(sl, x, cfg, prefix="m_")
        # shared attention block (weights closed over, not scanned)
        a, _ = tr._attn(shared, L.rms_norm(x, shared["ln1"]), cfg,
                        positions)
        x = x + a
        x = x + tr._dense_ffn(shared, L.rms_norm(x, shared["ln2"]), cfg)
        x = hint(x, "data", "model", None)  # sequence parallelism
        return x, None

    if cfg.remat:
        group = jax.checkpoint(
            group, policy=jax.checkpoint_policies.nothing_saveable)

    stk = jax.tree.map(
        lambda t: t.reshape((n_groups, every) + t.shape[1:]), m_stacks)
    x, _ = L.scan_layers(group, x, stk, cfg.unroll)
    x = L.rms_norm(x, params["final_norm"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


# ------------------------------------------------------------------ decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    apps = n_attn_apps(cfg)
    c = tr.cache_len(cfg, max_len)
    dt = jnp.dtype(cfg.dtype)
    return {
        "ssm": ssm_mod.init_state(cfg, batch),
        "k": jnp.zeros((apps, batch, cfg.n_kv_heads, c, cfg.head_dim), dt),
        "v": jnp.zeros((apps, batch, cfg.n_kv_heads, c, cfg.head_dim), dt),
    }


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    apps = n_attn_apps(cfg)
    c = tr.cache_len(cfg, max_len)
    dt = jnp.dtype(cfg.dtype)
    shp = (apps, batch, cfg.n_kv_heads, c, cfg.head_dim)
    return {
        "ssm": ssm_mod.state_specs(cfg, batch),
        "k": jax.ShapeDtypeStruct(shp, dt),
        "v": jax.ShapeDtypeStruct(shp, dt),
    }


def decode_step(params: Params, cfg: ModelConfig, cache: Dict,
                tokens: jax.Array, index: jax.Array):
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.full((1,), index, jnp.int32)
    shared = _shared_slice(params)
    every = cfg.shared_attn_every
    n_groups = cfg.n_layers // every
    m_stacks = {k: v for k, v in params.items() if k.startswith("m_")}

    def group(x, slices):
        slc, conv_st, ssm_st, kc, vc = slices
        new_conv, new_ssm = [], []
        for i in range(every):
            sl = {k: v[i] for k, v in slc.items()}
            x, st = ssm_mod.block_forward(
                sl, x, cfg, state={"conv": conv_st[i], "ssm": ssm_st[i]},
                prefix="m_")
            new_conv.append(st["conv"])
            new_ssm.append(st["ssm"])
        a, (nk, nv) = tr._attn(shared, L.rms_norm(x, shared["ln1"]), cfg,
                               positions, kv_cache=(kc, vc),
                               cache_index=index)
        x = x + a
        x = x + tr._dense_ffn(shared, L.rms_norm(x, shared["ln2"]), cfg)
        return x, (jnp.stack(new_conv), jnp.stack(new_ssm), nk, nv)

    stk = jax.tree.map(
        lambda t: t.reshape((n_groups, every) + t.shape[1:]), m_stacks)
    conv_stk = cache["ssm"]["conv"].reshape(
        (n_groups, every) + cache["ssm"]["conv"].shape[1:])
    ssm_stk = cache["ssm"]["ssm"].reshape(
        (n_groups, every) + cache["ssm"]["ssm"].shape[1:])

    x, (nc, ns, nk, nv) = L.scan_layers(
        group, x, (stk, conv_stk, ssm_stk, cache["k"], cache["v"]),
        cfg.unroll)
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    new_cache = {
        "ssm": {"conv": nc.reshape(cache["ssm"]["conv"].shape),
                "ssm": ns.reshape(cache["ssm"]["ssm"].shape)},
        "k": nk, "v": nv,
    }
    return logits, new_cache
