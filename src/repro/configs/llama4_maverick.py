"""Llama-4 Maverick 400B-A17B [hf:meta-llama]: interleaved MoE, 128e
top-1 + shared expert, early fusion (text backbone here; the vision
frontend is stubbed per the assignment)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    fsdp=True,  # params exceed per-chip HBM at TP=16: ZeRO-3 shard
    name="llama4-maverick-400b-a17b", family="moe", n_layers=48,
    d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128, d_ff=8192,
    vocab=202048, activation="swiglu", n_experts=128, top_k=1,
    moe_layer_period=2, shared_expert=True)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     head_dim=16, d_ff=128, vocab=256, n_experts=4,
                     top_k=1, moe_layer_period=2, remat=False)
