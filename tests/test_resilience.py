"""Fault-tolerant tuning runtime (``core.resilience``).

Covers the failure taxonomy, deterministic fault injection, deadlines
and guarded retry, the crash-safe persistent stores (corruption ->
``<path>.corrupt`` quarantine, checksum verification, version skew,
legacy format), candidate quarantine in the DSE tuning cache, plan
certification gating, and the headline robustness property: a fully
fault-injected measured exploration still returns a valid analytic
plan -- and never hangs, raises, or caches an uncertified winner.
"""
import json
import os
import time

import pytest

from repro.core import calibrate, dse, resilience
from repro.core import measure as measure_mod


# --------------------------------------------------------------------------
# Failure taxonomy
# --------------------------------------------------------------------------


@pytest.mark.parametrize("exc,kind", [
    (resilience.DeadlineExceeded("slow"), "timeout"),
    (NotImplementedError("no template"), "lower-unsupported"),
    (ValueError("bad shape"), "lower-error"),
    (TypeError("bad arg"), "lower-error"),
    (KeyError("missing"), "lower-error"),
    (IndexError("oob"), "lower-error"),
    (ZeroDivisionError("div"), "numeric-error"),
    (OSError("io blip"), "transient"),
    (MemoryError(), "transient"),
    (RuntimeError("xla: internal"), "compile-error"),
])
def test_classify_taxonomy(exc, kind):
    assert resilience.classify(exc) == kind


def test_classify_injected_and_unexpected():
    fault = resilience.InjectedFault("lower", "candidate 3")
    assert resilience.classify(fault) == "injected:lower"
    # a real bug (AttributeError etc.) is never an expected kind
    assert resilience.classify(AttributeError("bug")) \
        == "unexpected:AttributeError"
    assert not isinstance(AttributeError("bug"),
                          resilience.EXPECTED_ERRORS)


def test_timeout_classified_before_transient():
    # DeadlineExceeded IS a TimeoutError IS an OSError: the taxonomy
    # must not retry a deterministic hang as a "transient" blip
    assert isinstance(resilience.DeadlineExceeded("x"), OSError)
    assert "timeout" not in resilience.RETRYABLE_KINDS


# --------------------------------------------------------------------------
# Event log
# --------------------------------------------------------------------------


def test_event_log_counts_and_filters():
    resilience.record("time", "timeout", "k1", "quarantined", "slow")
    resilience.record("lower", "lower-error", "k2", "fallback")
    assert resilience.LOG.counts() == {"quarantined": 1, "fallback": 1}
    assert [e.key for e in resilience.LOG.events(stage="time")] == ["k1"]
    assert [e.key for e in resilience.LOG.events(action="fallback")] \
        == ["k2"]


def test_record_once_dedupes_hot_path():
    for _ in range(5):
        resilience.record_once("lower", "lower-unsupported", "same-key",
                               "fallback")
    assert len(resilience.LOG.events()) == 1
    resilience.LOG.reset()
    assert resilience.LOG.counts() == {}
    # reset clears the dedup memory too
    resilience.record_once("lower", "lower-unsupported", "same-key",
                           "fallback")
    assert len(resilience.LOG.events()) == 1


# --------------------------------------------------------------------------
# Deterministic fault injection
# --------------------------------------------------------------------------


def test_fault_injector_parse():
    inj = resilience.FaultInjector.parse("lower:0.5, time:1,certify")
    assert inj.specs == {"lower": 0.5, "time": 1.0, "certify": 1.0}
    with pytest.raises(ValueError):
        resilience.FaultInjector.parse("lower:2")       # p outside [0,1]
    with pytest.raises(ValueError):
        resilience.FaultInjector.parse(":0.5")          # empty site
    with pytest.raises(ValueError):
        resilience.FaultInjector.parse("lower:abc")     # not a number


def _fault_pattern(inj, site, n=64):
    hits = []
    for i in range(n):
        try:
            inj.maybe_fail(site)
        except resilience.InjectedFault:
            hits.append(i)
    return hits


def test_fault_injector_deterministic():
    a = resilience.FaultInjector({"lower": 0.5}, seed=7)
    b = resilience.FaultInjector({"lower": 0.5}, seed=7)
    pat = _fault_pattern(a, "lower")
    assert pat == _fault_pattern(b, "lower")
    assert 0 < len(pat) < 64  # p=0.5 over 64 draws: some, not all
    c = resilience.FaultInjector({"lower": 0.5}, seed=8)
    assert pat != _fault_pattern(c, "lower")


def test_fault_injector_edge_probabilities():
    inj = resilience.FaultInjector({"lower": 1.0, "time": 0.0})
    assert len(_fault_pattern(inj, "lower", 8)) == 8
    assert _fault_pattern(inj, "time", 8) == []
    assert _fault_pattern(inj, "unlisted-site", 8) == []


def test_ambient_injector_follows_env(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", "lower:1")
    with pytest.raises(resilience.InjectedFault):
        resilience.inject("lower", "probe")
    monkeypatch.delenv("REPRO_FAULTS")
    resilience.inject("lower", "probe")  # no faults configured: no-op


# --------------------------------------------------------------------------
# Deadlines + guarded calls
# --------------------------------------------------------------------------


def test_run_with_deadline_completes_and_propagates():
    assert resilience.run_with_deadline(lambda: 41 + 1, 5.0) == 42
    assert resilience.run_with_deadline(lambda: "inline", 0) == "inline"

    def boom():
        raise ValueError("from worker")

    with pytest.raises(ValueError, match="from worker"):
        resilience.run_with_deadline(boom, 5.0)


def test_run_with_deadline_times_out():
    t0 = time.monotonic()
    with pytest.raises(resilience.DeadlineExceeded):
        resilience.run_with_deadline(lambda: time.sleep(10), 0.2,
                                     label="sleeper")
    assert time.monotonic() - t0 < 5.0  # abandoned, not joined


def test_call_guarded_retries_transient_only():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("resource blip")
        return "ok"

    pol = resilience.Policy(timeout_s=0, retries=2, backoff_s=0.0)
    assert resilience.call_guarded(flaky, stage="time", key="k",
                                   policy=pol) == "ok"
    assert calls["n"] == 3
    assert len(resilience.LOG.events(action="retried")) == 2


def test_call_guarded_no_retry_for_deterministic_failures():
    calls = {"n": 0}

    def bad():
        calls["n"] += 1
        raise ValueError("template mismatch")

    pol = resilience.Policy(timeout_s=0, retries=3, backoff_s=0.0)
    with pytest.raises(resilience.CandidateFailure) as ei:
        resilience.call_guarded(bad, stage="lower", key="k", policy=pol)
    assert ei.value.kind == "lower-error"
    assert calls["n"] == 1  # retrying a deterministic failure is waste


def test_call_guarded_timeout_becomes_candidate_failure():
    pol = resilience.Policy(timeout_s=0.2, retries=1, backoff_s=0.0)
    with pytest.raises(resilience.CandidateFailure) as ei:
        resilience.call_guarded(lambda: time.sleep(10), stage="time",
                                key="k", policy=pol)
    assert ei.value.kind == "timeout"


def test_call_guarded_unexpected_bug_propagates():
    def bug():
        raise AttributeError("a real repo bug")

    with pytest.raises(AttributeError):
        resilience.call_guarded(bug, stage="lower", key="k",
                                policy=resilience.Policy(timeout_s=0))


def test_policy_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_TIMEOUT_S", "7.5")
    monkeypatch.setenv("REPRO_RETRIES", "3")
    monkeypatch.setenv("REPRO_BACKOFF_S", "0.01")
    monkeypatch.setenv("REPRO_CERTIFY", "0")
    pol = resilience.default_policy()
    assert pol == resilience.Policy(timeout_s=7.5, retries=3,
                                    backoff_s=0.01, certify=False)
    assert resilience.resolve_policy(None) == pol
    mine = resilience.Policy(timeout_s=1)
    assert resilience.resolve_policy(mine) is mine


# --------------------------------------------------------------------------
# Crash-safe stores
# --------------------------------------------------------------------------


def test_store_roundtrip_and_missing(tmp_path):
    path = str(tmp_path / "store.json")
    assert resilience.load_store(path) == {}  # missing: silently empty
    resilience.save_store(path, {"a": {"x": 1}})
    assert resilience.load_store(path) == {"a": {"x": 1}}
    doc = json.load(open(path))
    assert doc["__meta__"]["version"] == resilience.STORE_VERSION
    assert doc["__meta__"]["checksum"] \
        == resilience._payload_checksum(doc["data"])


def test_truncated_store_quarantined_with_named_warning(tmp_path):
    path = str(tmp_path / "store.json")
    with open(path, "w") as f:
        f.write('{"a": {"x": 1')  # crashed mid-write
    with pytest.warns(UserWarning) as rec:
        assert resilience.load_store(path, label="test store") == {}
    msgs = [str(w.message) for w in rec]
    assert any(path in m and "invalid JSON" in m for m in msgs)
    assert os.path.exists(path + ".corrupt")  # evidence survives
    assert not os.path.exists(path)
    assert open(path + ".corrupt").read() == '{"a": {"x": 1'
    assert resilience.LOG.events(stage="store", action="rebuilt")


def test_non_object_store_quarantined(tmp_path):
    path = str(tmp_path / "store.json")
    with open(path, "w") as f:
        json.dump([1, 2, 3], f)
    with pytest.warns(UserWarning, match="list"):
        assert resilience.load_store(path) == {}
    assert os.path.exists(path + ".corrupt")


def test_checksum_mismatch_quarantined(tmp_path):
    path = str(tmp_path / "store.json")
    doc = {"__meta__": {"version": resilience.STORE_VERSION,
                        "checksum": "0" * 16},
           "data": {"a": {"x": 1}}}
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.warns(UserWarning, match="checksum mismatch"):
        assert resilience.load_store(path) == {}
    assert os.path.exists(path + ".corrupt")


def test_version_skew_fresh_start_no_quarantine(tmp_path):
    path = str(tmp_path / "store.json")
    resilience.save_store(path, {"a": {"x": 1}}, version=999)
    assert resilience.load_store(path) == {}
    # the file is healthy, just from another revision: keep it in place
    assert os.path.exists(path)
    assert not os.path.exists(path + ".corrupt")
    skew = resilience.LOG.events(stage="store", action="skipped")
    assert skew and skew[0].kind == "store-version-skew"


def test_legacy_flat_store_accepted(tmp_path):
    path = str(tmp_path / "store.json")
    with open(path, "w") as f:
        json.dump({"plan-key": {"sizes": {}}}, f)  # pre-envelope format
    assert resilience.load_store(path) == {"plan-key": {"sizes": {}}}


def test_locked_update_merges_concurrent_keys(tmp_path):
    path = str(tmp_path / "store.json")
    resilience.locked_update(path, lambda d: d.__setitem__("a", 1))
    # a second writer (fresh read of the same file) adds its own key:
    # both survive -- last-writer-wins would have dropped "a"
    out = resilience.locked_update(path, lambda d: d.__setitem__("b", 2))
    assert out == {"a": 1, "b": 2}
    assert resilience.load_store(path) == {"a": 1, "b": 2}


def test_atomic_write_swallows_readonly_fs(tmp_path):
    target = tmp_path / "ro"
    target.mkdir()
    os.chmod(target, 0o500)
    try:
        resilience.save_store(str(target / "s.json"), {"a": 1})  # no raise
    finally:
        os.chmod(target, 0o700)


# --------------------------------------------------------------------------
# Store corruption recovery through each consumer
# --------------------------------------------------------------------------


def test_tuning_cache_survives_corruption(tmp_path):
    path = str(tmp_path / "dse_cache.json")
    with open(path, "w") as f:
        f.write("not json at all")
    tc = dse.TuningCache(path)
    with pytest.warns(UserWarning, match="DSE tuning cache"):
        assert tc.get("anything") is None
    assert os.path.exists(path + ".corrupt")
    # and the rebuilt cache is writable again
    plan = dse.TilePlan(sizes={"t": (128,)}, traffic_words=1,
                        vmem_bytes=2, modeled_seconds=3.0)
    tc.put("k", plan)
    again = dse.TuningCache(path).get("k")
    assert again is not None and again.sizes == {"t": (128,)}


def test_timing_db_survives_corruption(tmp_path):
    path = str(tmp_path / "timing.json")
    with open(path, "w") as f:
        f.write('{"half": ')
    db = measure_mod.TimingDB(path)
    with pytest.warns(UserWarning, match="timing"):
        assert db.get("some-key") is None
    assert os.path.exists(path + ".corrupt")
    m = measure_mod.Measurement(median_s=1e-3, mean_s=1e-3, min_s=1e-3,
                                max_s=1e-3, repeat=1, warmup=0)
    db.put("some-key", m)
    got = measure_mod.TimingDB(path).get("some-key")
    assert got is not None and got.median_s == pytest.approx(1e-3)


def test_calibration_profile_survives_corruption(tmp_path, monkeypatch):
    path = str(tmp_path / "calib.json")
    monkeypatch.setenv("REPRO_CALIB_PROFILE", path)
    with open(path, "w") as f:
        f.write("\x00\x01 garbage")
    with pytest.warns(UserWarning, match="calibration profile"):
        assert calibrate.load_profile(path=path) is None
    assert os.path.exists(path + ".corrupt")
    assert calibrate.active_profile_hash(path=path) == "uncalibrated"


# --------------------------------------------------------------------------
# Candidate quarantine in the tuning cache
# --------------------------------------------------------------------------


def test_tuning_cache_quarantine_roundtrip(tmp_path):
    path = str(tmp_path / "dse_cache.json")
    tc = dse.TuningCache(path)
    assert tc.quarantined("time|cand") is None
    tc.quarantine("time|cand", "compile-error", "xla fell over")
    assert tc.quarantined("time|cand") \
        == {"kind": "compile-error", "detail": "xla fell over"}
    # persisted: a fresh process sees the same quarantine, and the
    # reserved key never reads back as a plan
    tc2 = dse.TuningCache(path)
    assert tc2.quarantined("time|cand") is not None
    assert tc2.get(dse.QUARANTINE_KEY) is None
    # plans and quarantine share the document without clobbering
    plan = dse.TilePlan(sizes={"t": (64,)}, traffic_words=1,
                        vmem_bytes=2, modeled_seconds=3.0)
    tc2.put("plan-key", plan)
    tc3 = dse.TuningCache(path)
    assert tc3.get("plan-key") is not None
    assert tc3.quarantined("time|cand") is not None


# --------------------------------------------------------------------------
# Fault-injected exploration: degrade, never die
# --------------------------------------------------------------------------


def _drop_plans(path):
    """Remove cached plans (keep the quarantine) so a re-exploration
    cannot short-circuit on the plan cache."""
    data = resilience.load_store(path)
    for k in [k for k in data if k != dse.QUARANTINE_KEY]:
        del data[k]
    resilience.save_store(path, data)


def test_explore_with_lowering_faults_falls_back(tmp_path, monkeypatch):
    path = str(tmp_path / "dse_cache.json")
    monkeypatch.setenv("REPRO_FAULTS", "lower:1")
    p = dse.filter_reduce_program(4096)
    plan = dse.explore(p, measure="top_k", top_k=2, repeat=1, warmup=0,
                       cache=dse.TuningCache(path), timing_db=False)
    # every candidate's lowering failed: the analytic argmin ships
    assert plan.measured is False and plan.timed == 0
    assert plan.sizes and plan.vmem_bytes > 0
    assert resilience.LOG.events(stage="time", action="quarantined")
    assert resilience.LOG.events(action="fallback")
    # quarantine persisted inside the cache document
    data = resilience.load_store(path)
    q = data.get(dse.QUARANTINE_KEY, {})
    assert q and all(v["kind"] == "injected:lower" for v in q.values())

    # the analytic plan is numerically sound: with faults off, its
    # tile sizes certify against the codegen_jax oracle
    monkeypatch.delenv("REPRO_FAULTS")
    ok, why = resilience.certify_tile_plan(p, plan.sizes)
    assert ok, why

    # quarantined candidates are never re-attempted: re-explore (plan
    # cache emptied, faults off) skips them without lowering or timing
    _drop_plans(path)
    resilience.LOG.reset()
    plan2 = dse.explore(p, measure="top_k", top_k=2, repeat=1, warmup=0,
                        cache=dse.TuningCache(path), timing_db=False)
    assert plan2.sizes == plan.sizes
    assert resilience.LOG.events(stage="time", action="skipped")
    assert not resilience.LOG.events(action="quarantined")


def test_explore_pipeline_with_timing_faults_falls_back(tmp_path,
                                                        monkeypatch):
    path = str(tmp_path / "dse_cache.json")
    monkeypatch.setenv("REPRO_FAULTS", "time:1")
    pipe = dse.filter_fold_pipeline(4096)
    plan = dse.explore_pipeline(pipe, measure="top_k", top_k=2,
                                repeat=1, warmup=0,
                                cache=dse.TuningCache(path),
                                timing_db=False)
    assert isinstance(plan, dse.PipelinePlan)
    assert plan.measured is False and plan.block > 0
    assert resilience.LOG.events(stage="time", action="quarantined")
    q = resilience.load_store(path).get(dse.QUARANTINE_KEY, {})
    assert q and all(v["kind"] == "injected:time" for v in q.values())
    # the analytic fallback still computes the right numbers
    monkeypatch.delenv("REPRO_FAULTS")
    ok, why = resilience.certify_pipeline_plan(pipe, plan)
    assert ok, why


def test_explore_measured_winner_certifies(tmp_path):
    # no faults: the measured path times, certifies and promotes
    p = dse.filter_reduce_program(4096)
    plan = dse.explore(p, measure="top_k", top_k=2, repeat=1, warmup=0,
                       cache=dse.TuningCache(str(tmp_path / "c.json")),
                       timing_db=False)
    assert plan.measured is True and plan.timed > 0
    assert not resilience.LOG.events(action="quarantined")


def test_failed_certification_never_promoted(tmp_path, monkeypatch):
    path = str(tmp_path / "dse_cache.json")
    monkeypatch.setattr(resilience, "certify_tile_plan",
                        lambda *a, **k: (False, "forced: wrong numbers"))
    p = dse.filter_reduce_program(4096)
    plan = dse.explore(p, measure="top_k", top_k=2, repeat=1, warmup=0,
                       cache=dse.TuningCache(path), timing_db=False)
    # candidates timed fine but none certified: the measured winner is
    # rejected and the analytic argmin ships instead
    assert plan.measured is False and plan.timed == 0
    assert resilience.LOG.events(stage="certify", action="quarantined")
    assert resilience.LOG.events(action="fallback")
    data = resilience.load_store(path)
    q = data.get(dse.QUARANTINE_KEY, {})
    certs = {k: v for k, v in q.items() if k.startswith("certify|")}
    assert certs \
        and all(v["kind"] == "certify-failed" for v in certs.values())
    # nothing cached claims to be measured
    for key, doc in data.items():
        if key == dse.QUARANTINE_KEY:
            continue
        assert not doc.get("measured"), \
            f"uncertified winner cached under {key}"


def test_certify_disabled_by_policy(tmp_path, monkeypatch):
    # certify=False promotes the fastest timing without an oracle run
    calls = {"n": 0}

    def spy(*a, **k):
        calls["n"] += 1
        return (True, "ok")

    monkeypatch.setattr(resilience, "certify_tile_plan", spy)
    pol = resilience.Policy(timeout_s=0, certify=False)
    p = dse.filter_reduce_program(4096)
    plan = dse.explore(p, measure="top_k", top_k=1, repeat=1, warmup=0,
                       cache=False, timing_db=False, policy=pol)
    assert plan.measured is True
    assert calls["n"] == 0
