"""Mamba-2 SSD chunked scan in Pallas.

The SSD chunking of Mamba-2 (arXiv:2405.21060) is *exactly* the paper's
Table-1 MultiFold strip-mining rule applied to the state recurrence
(DESIGN.md §4): the sequence fold splits into an intra-chunk pattern
(dense matmuls on a tile -- MXU work) plus an inter-chunk combine (the
decayed state carry), with the chunk state forwarded between strided
iterations in VMEM scratch.

Grid: (batch, heads, n_chunks) with chunks innermost (sequential on TPU,
so the scratch state carry is well-defined).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INTERPRET = True


def _auto_blocks(seq: int, n: int, dh: int,
                 measure: Optional[str] = None, policy=None,
                 options=None) -> int:
    from .ops import resolve_plan  # shared memoized selector front door
    chunk, _ = resolve_plan("scan", seq, n, dh, measure=measure,
                            policy=policy, options=options)
    return chunk


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, h_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[0]                                  # scalar decay rate (<0)
    x = x_ref[0, :, 0, :].astype(jnp.float32)     # (L, dh)
    dt = dt_ref[0, :, 0].astype(jnp.float32)      # (L,)
    B = b_ref[0].astype(jnp.float32)              # (L, n)
    C = c_ref[0].astype(jnp.float32)              # (L, n)

    s = A * dt                                    # (L,)
    cum = jnp.cumsum(s)                           # (L,)
    # intra-chunk: M[t,u] = exp(cum_t - cum_u) * dt_u  for u <= t
    lmask = (jax.lax.iota(jnp.int32, chunk)[:, None]
             >= jax.lax.iota(jnp.int32, chunk)[None, :])
    M = jnp.where(lmask, jnp.exp(cum[:, None] - cum[None, :])
                  * dt[None, :], 0.0)             # (L, L)
    scores = jnp.dot(C, B.T, preferred_element_type=jnp.float32)  # (L, L)
    y_intra = jnp.dot(scores * M, x,
                      preferred_element_type=jnp.float32)         # (L, dh)
    # inter-chunk: contribution of the carried state
    h = h_ref[...]                                # (n, dh) fp32
    y_state = jnp.exp(cum)[:, None] * jnp.dot(
        C, h, preferred_element_type=jnp.float32)                 # (L, dh)
    y_ref[0, :, 0, :] = (y_intra + y_state).astype(y_ref.dtype)
    # state carry: h' = exp(cum_L) h + sum_u exp(cum_L - cum_u) dt_u B_u x_u
    w = jnp.exp(cum[-1] - cum) * dt               # (L,)
    h_ref[...] = (jnp.exp(cum[-1]) * h
                  + jnp.dot((B * w[:, None]).T, x,
                            preferred_element_type=jnp.float32))


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, *, chunk: int = 128, auto_tile: bool = False,
             measure: Optional[str] = None, policy=None, options=None,
             interpret: Optional[bool] = None) -> jax.Array:
    """See ref.ssd_scan for semantics.  seq must divide ``chunk``.

    ``auto_tile=True`` picks the chunk length by DSE on the sequence-fold
    proxy (``repro.core.dse.scan_program``); ``policy`` (a
    ``core.resilience.Policy``) bounds any measured exploration."""
    bsz, seq, h, dh = x.shape
    n = B.shape[-1]
    if auto_tile:
        chunk = _auto_blocks(seq, n, dh, measure, policy, options)
    chunk = min(chunk, seq)
    assert seq % chunk == 0, (seq, chunk)
    nc = seq // chunk
    grid = (bsz, h, nc)

    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, hh, c: (hh,)),                # A
            pl.BlockSpec((1, chunk, 1, dh),
                         lambda b, hh, c: (b, c, hh, 0)),              # x
            pl.BlockSpec((1, chunk, 1), lambda b, hh, c: (b, c, hh)),  # dt
            pl.BlockSpec((1, chunk, n), lambda b, hh, c: (b, c, 0)),   # B
            pl.BlockSpec((1, chunk, n), lambda b, hh, c: (b, c, 0)),   # C
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, dh),
                               lambda b, hh, c: (b, c, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, seq, h, dh), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, dh), jnp.float32)],
        interpret=INTERPRET if interpret is None else interpret,
    )(A, x, dt, B, C)
