"""benchmarks/check_regression.py (the CI perf-regression gate) and the
benchmarks/run.py --json robustness bugfix.

The gate must demonstrably fail on a synthetic 10% modeled-traffic
regression (ISSUE-3 acceptance) and pass when fresh numbers match the
committed baseline; run.py --json must produce a valid document even
when no rows were emitted or a section died mid-run.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))
import check_regression as cr  # noqa: E402


BASE = {
    "tpchq6": {"fused": 12289, "unfused": 20481, "ratio": 1.67},
    "kmeans": {"fused": 4360, "unfused": 9224, "ratio": 2.12},
}


def _rows(fused_by_name):
    rows = []
    for name, (fused, unfused, ratio) in fused_by_name.items():
        rows += [
            {"section": "fused", "name": f"fused/{name}/fused",
             "traffic_words": fused},
            {"section": "fused", "name": f"fused/{name}/unfused",
             "traffic_words": unfused},
            {"section": "fused", "name": f"fused/{name}/traffic_ratio",
             "traffic_ratio": ratio},
        ]
    return rows


def test_gate_passes_when_unchanged():
    fresh = cr.extract_traffic(_rows({
        "tpchq6": (12289, 20481, 1.67), "kmeans": (4360, 9224, 2.12)}))
    failures, notes = cr.compare(BASE, fresh)
    assert failures == [] and notes == []


def test_gate_fails_on_10pct_traffic_regression():
    fresh = cr.extract_traffic(_rows({
        "tpchq6": (int(12289 * 1.10), 20481, 1.52),    # +10% fused words
        "kmeans": (4360, 9224, 2.12)}))
    failures, _ = cr.compare(BASE, fresh, tolerance=0.05)
    assert any("tpchq6" in f and "regressed" in f for f in failures)


def test_gate_allows_within_tolerance():
    fresh = cr.extract_traffic(_rows({
        "tpchq6": (int(12289 * 1.04), 20481, 1.67),    # +4% < 5%
        "kmeans": (4360, 9224, 2.12)}))
    failures, _ = cr.compare(BASE, fresh, tolerance=0.05)
    assert failures == []


def test_gate_fails_on_ratio_erosion():
    fresh = cr.extract_traffic(_rows({
        "tpchq6": (12289, 13000, 1.06),   # fused flat, win collapsed
        "kmeans": (4360, 9224, 2.12)}))
    failures, _ = cr.compare(BASE, fresh)
    assert any("win eroded" in f for f in failures)


def test_gate_fails_on_missing_pipeline():
    fresh = cr.extract_traffic(_rows({"kmeans": (4360, 9224, 2.12)}))
    failures, _ = cr.compare(BASE, fresh)
    assert any("missing" in f for f in failures)


def test_gate_notes_new_pipeline_without_failing():
    fresh = cr.extract_traffic(_rows({
        "tpchq6": (12289, 20481, 1.67), "kmeans": (4360, 9224, 2.12),
        "brand_new": (1, 2, 2.0)}))
    failures, notes = cr.compare(BASE, fresh)
    assert failures == []
    assert any("brand_new" in n for n in notes)


def test_cli_exit_codes(tmp_path):
    bench = tmp_path / "BENCH_x.json"
    bench.write_text(json.dumps({"rev": "x", "rows": _rows({
        "tpchq6": (12289, 20481, 1.67),
        "kmeans": (4360, 9224, 2.12)})}))
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps({"pipelines": BASE}))
    assert cr.main(["--bench", str(bench),
                    "--baseline", str(baseline)]) == 0
    bad = tmp_path / "BENCH_y.json"
    bad.write_text(json.dumps({"rev": "y", "rows": _rows({
        "tpchq6": (int(12289 * 1.10), 20481, 1.52),
        "kmeans": (4360, 9224, 2.12)})}))
    assert cr.main(["--bench", str(bad),
                    "--baseline", str(baseline)]) == 1


def test_cli_picks_newest_bench_by_mtime(tmp_path):
    old = tmp_path / "BENCH_zzz.json"   # name sorts LAST, mtime oldest
    old.write_text(json.dumps({"rows": _rows({
        "tpchq6": (99999, 1, 1.0)})}))
    os.utime(old, (1, 1))
    new = tmp_path / "BENCH_aaa.json"
    new.write_text(json.dumps({"rows": _rows({
        "tpchq6": (12289, 20481, 1.67),
        "kmeans": (4360, 9224, 2.12)})}))
    rows = cr.load_rows(str(tmp_path / "BENCH_*.json"))
    assert cr.extract_traffic(rows)["tpchq6"]["fused"] == 12289


def test_cli_refuses_crashed_bench_doc(tmp_path):
    """A BENCH json carrying run.py's mid-crash 'error' field has
    partial rows: the gate must refuse it, and --write-baseline must
    not silently shrink the gated pipeline set from it."""
    crashed = tmp_path / "BENCH_c.json"
    crashed.write_text(json.dumps({
        "rev": "c", "error": "RuntimeError: section exploded",
        "rows": _rows({"tpchq6": (12289, 20481, 1.67)})}))
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps({"pipelines": BASE}))
    assert cr.main(["--bench", str(crashed),
                    "--baseline", str(baseline)]) == 1
    out = tmp_path / "new_baseline.json"
    assert cr.main(["--bench", str(crashed),
                    "--write-baseline", str(out)]) == 1
    assert not out.exists()


def test_write_baseline_roundtrip(tmp_path):
    bench = tmp_path / "BENCH_x.json"
    bench.write_text(json.dumps({"rev": "x", "rows": _rows({
        "tpchq6": (12289, 20481, 1.67)})}))
    out = tmp_path / "baseline.json"
    assert cr.main(["--bench", str(bench),
                    "--write-baseline", str(out)]) == 0
    doc = json.load(open(out))
    assert doc["pipelines"]["tpchq6"]["fused"] == 12289


def test_committed_baseline_matches_current_model():
    """The committed baseline must agree with the cost model of this
    revision (within the gate's own tolerance) -- otherwise CI is
    already red on merge."""
    from repro.core import dse
    from repro.patterns.analytics import PIPELINES
    path = os.path.join(os.path.dirname(__file__), "..",
                        "benchmarks", "baseline_traffic.json")
    baseline = json.load(open(path))["pipelines"]
    assert set(baseline) == set(PIPELINES)
    fresh = {}
    for name, builder in PIPELINES.items():
        pipe, _, _ = builder()
        plan = dse.explore_pipeline(pipe, cache=False)
        fresh[name] = {"fused": plan.traffic_words,
                       "unfused": plan.unfused_traffic_words,
                       "ratio": round(plan.traffic_ratio, 2)}
    failures, _ = cr.compare(baseline, fresh)
    assert failures == [], failures


# ----------------------------------------------- run.py --json bugfix
def test_write_json_emits_valid_empty_document(tmp_path, monkeypatch):
    import run as runmod
    monkeypatch.setattr(runmod, "JSON_ROWS", [])
    path = runmod.write_json(str(tmp_path))
    doc = json.load(open(path))
    assert doc["rows"] == [] and "rev" in doc


def test_json_written_even_when_section_crashes(tmp_path, monkeypatch):
    import run as runmod
    monkeypatch.setattr(runmod, "ROWS", [])
    monkeypatch.setattr(runmod, "JSON_ROWS", [])

    def boom():
        raise RuntimeError("section exploded")

    monkeypatch.setitem(runmod.SECTIONS, "table2", boom)
    with pytest.raises(RuntimeError, match="exploded"):
        runmod.main(["--only", "table2", "--json", str(tmp_path)])
    files = [f for f in os.listdir(tmp_path) if f.startswith("BENCH_")]
    assert len(files) == 1
    doc = json.load(open(tmp_path / files[0]))
    assert doc["rows"] == []
    assert "section exploded" in doc.get("error", "")
