"""Sharding rules: params, optimizer state, activations, caches.

Single source of truth for how every tensor maps onto the production
mesh.  Divisibility is always checked -- dims that do not divide the
mesh axis (granite's 49155 vocab, internvl's 14 heads) silently fall
back to replication for that dim, which GSPMD handles with local
all-gathers (noted in EXPERIMENTS.md §Dry-run).

Param rules (Megatron pairing -- one all-reduce per sublayer):
  wq/wk/wv : shard output columns over "model"
  wo       : shard input rows over "model"
  w1/w3    : columns over "model";  w2: rows over "model"
  experts  : expert dim over "model" when divisible (EP), else the
             ffn dim (TP inside experts -- Mixtral's 8 experts on a
             16-way axis)
  embed/lm_head: vocab dim over "model"
ZeRO-1: optimizer m/v/ef additionally shard their largest replicated
dim over ("pod","data") when divisible.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

# param-name suffix -> spec template (dims right-aligned onto the shape;
# leading stacked layer dims are None)
_RULES = {
    "embed": ("model", None),
    "lm_head": (None, "model"),
    "wq": (None, "model"), "wk": (None, "model"), "wv": (None, "model"),
    "bq": ("model",), "bk": ("model",), "bv": ("model",),
    "wo": ("model", None),
    "w1": (None, "model"), "w3": (None, "model"), "w2": ("model", None),
    # moe: expert dim first (EP)
    "moe_we1": ("model", None, None), "moe_we3": ("model", None, None),
    "moe_we2": ("model", None, None),
    "moe_router": (None, "model"),
    "moe_ws1": (None, "model"), "moe_ws3": (None, "model"),
    "moe_ws2": ("model", None),
    # ssm blocks
    "m_in_proj": (None, "model"), "m_out_proj": ("model", None),
    "m_conv_w": (None, "model"),
    # shared attention block (zamba)
    "s_wq": (None, "model"), "s_wk": (None, "model"),
    "s_wv": (None, "model"), "s_wo": ("model", None),
    "s_w1": (None, "model"), "s_w3": (None, "model"),
    "s_w2": ("model", None),
}

_MOE_EP_FALLBACK = {  # experts don't divide: TP inside experts instead
    "moe_we1": (None, None, "model"), "moe_we3": (None, None, "model"),
    "moe_we2": (None, "model", None),
}


def _fit(spec: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Right-align the rule onto the shape, pad leading None, and drop
    axes that do not divide."""
    full = (None,) * (len(shape) - len(spec)) + tuple(spec)
    fixed = []
    for dim, ax in zip(shape, full):
        if ax is None:
            fixed.append(None)
            continue
        size = int(np.prod([mesh.shape[a] for a in
                            (ax if isinstance(ax, tuple) else (ax,))]))
        fixed.append(ax if dim % size == 0 else None)
    return P(*fixed)


def param_sharding(cfg: ModelConfig, mesh: Mesh,
                   param_specs: Dict[str, Any]) -> Dict[str, NamedSharding]:
    out = {}
    n_model = mesh.shape["model"]
    dax = _data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in dax]))
    for name, spec in param_specs.items():
        shape = spec.shape
        rule = None
        for suffix, r in _RULES.items():
            if name == suffix or name.endswith(suffix):
                rule = r
                break
        if rule is None:
            out[name] = NamedSharding(mesh, P())
            continue
        if name in _MOE_EP_FALLBACK and shape[-3] % n_model != 0:
            rule = _MOE_EP_FALLBACK[name]
        pspec = _fit(rule, shape, mesh)
        if cfg.fsdp and len(shape) >= 2:
            # ZeRO-3: also shard a still-replicated divisible dim over
            # data(+pod); GSPMD all-gathers per layer (FSDP).  Prefer a
            # WEIGHT dim over the stacked layer dim (dim 0 of >=3-D
            # params): sharding the scan axis makes the backward scan
            # accumulate FULL stacked fp32 grads before reduce-scatter
            # (58 GB for qwen2-72b; see EXPERIMENTS.md §Perf).
            parts = list(pspec) + [None] * (len(shape) - len(pspec))
            used = {a for ax in parts
                    for a in (ax if isinstance(ax, tuple) else (ax,)) if a}
            free = tuple(a for a in dax if a not in used)
            if free:
                fsize = int(np.prod([mesh.shape[a] for a in free]))
                order = list(range(len(shape)))
                if len(shape) >= 3:
                    order = order[1:] + [0]  # weight dims first
                for di in order:
                    if parts[di] is None and shape[di] % fsize == 0:
                        parts[di] = free if len(free) > 1 else free[0]
                        break
            pspec = P(*parts)
        out[name] = NamedSharding(mesh, pspec)
    return out


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return (("pod", "data") if "pod" in mesh.shape.keys() else ("data",))


def opt_state_sharding(cfg: ModelConfig, mesh: Mesh, param_specs,
                       opt_specs) -> Any:
    """ZeRO-1: m/v/ef shard like their param, plus the first still-
    replicated dim shards over the data(+pod) axes when divisible."""
    psh = param_sharding(cfg, mesh, param_specs)
    dax = _data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in dax]))

    def zero1(name, spec):
        base = psh[name].spec
        parts = list(base) + [None] * (len(spec.shape) - len(base))
        used = set()
        for ax in parts:
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                if a:
                    used.add(a)
        free = tuple(a for a in dax if a not in used)
        if free:
            fsize = int(np.prod([mesh.shape[a] for a in free]))
            for d, (dim, ax) in enumerate(zip(spec.shape, parts)):
                if ax is None and dim % fsize == 0:
                    parts[d] = free if len(free) > 1 else free[0]
                    break
        return NamedSharding(mesh, P(*parts))

    from repro.optim.adamw import AdamWState

    def map_tree(tree):
        return {k: zero1(k, v) for k, v in tree.items()}

    return AdamWState(
        step=NamedSharding(mesh, P()),
        m=map_tree(opt_specs.m), v=map_tree(opt_specs.v),
        ef=None if opt_specs.ef is None else map_tree(opt_specs.ef))


def batch_sharding(mesh: Mesh, specs: Dict[str, Any]
                   ) -> Dict[str, NamedSharding]:
    dax = _data_axes(mesh)
    ax = dax if len(dax) > 1 else dax[0]
    out = {}
    for name, spec in specs.items():
        parts = [None] * len(spec.shape)
        if spec.shape and spec.shape[0] > 1:
            parts[0] = ax
        out[name] = NamedSharding(mesh, P(*parts))
    return out


def cache_sharding(cfg: ModelConfig, mesh: Mesh, cache_specs) -> Any:
    """KV/SSM caches: batch over data(+pod), heads over model."""
    dax = _data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in dax]))
    n_model = mesh.shape["model"]
    ax = dax if len(dax) > 1 else dax[0]

    def one(spec):
        # layouts: (L, B, H, C, dh) or (L, B, K, C) or (L, B, H, N, dh)
        parts = [None] * len(spec.shape)
        if len(spec.shape) >= 2 and spec.shape[1] % dsize == 0:
            parts[1] = ax
        if len(spec.shape) >= 3 and spec.shape[2] % n_model == 0:
            parts[2] = "model"
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(one, cache_specs)
