"""Nemotron-4-15B [arXiv:2402.16819]: dense GQA, squared-ReLU FFN."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense", n_layers=32, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=24576, vocab=256000,
    activation="squared_relu")

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     head_dim=16, d_ff=128, vocab=256, remat=False)
