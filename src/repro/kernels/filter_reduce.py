"""Filter+reduce kernel (TPC-H Q6 shape): predicate mask, weighted sum.

The FlatMap(filter)+fold fusion of the paper lowered to TPU: the FPGA
streams records through a predicate FIFO into a reduction tree; here
each tile is masked on the VPU and reduced into a revisited scalar
accumulator block -- the dynamic-size FIFO disappears because the
reduction consumes values in place (the paper's vertical fusion).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _auto_blocks(t: int, measure: Optional[str] = None,
                 policy=None, options=None) -> int:
    from .ops import resolve_plan  # shared memoized selector front door
    bt, _ = resolve_plan("filter_reduce", t, measure=measure,
                         policy=policy, options=options)
    return bt


def _fr_kernel(x_ref, w_ref, lo_ref, hi_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    lo = lo_ref[0]
    hi = hi_ref[0]
    pred = (x >= lo) & (x < hi)
    o_ref[0, 0] += jnp.sum(jnp.where(pred, x * w, 0.0))


def filter_reduce(x: jax.Array, weight: jax.Array, lo, hi, *,
                  block_t: int = 1024, auto_tile: bool = False,
                  measure: Optional[str] = None, policy=None,
                  options=None,
                  interpret: Optional[bool] = None) -> jax.Array:
    """``auto_tile=True`` picks block_t by DSE on the fused filter+fold
    proxy (``repro.core.dse.filter_reduce_program``); ``measure="top_k"``
    backs the choice with real timings (hybrid DSE); ``policy`` (a
    ``core.resilience.Policy``) bounds the measured exploration;
    ``options`` (a ``core.dse.Options``) packs any exploration option."""
    (t,) = x.shape
    if auto_tile:
        block_t = _auto_blocks(t, measure, policy, options)
    block_t = min(block_t, t)
    assert t % block_t == 0
    lo = jnp.asarray([lo], jnp.float32)
    hi = jnp.asarray([hi], jnp.float32)
    out = pl.pallas_call(
        _fr_kernel,
        grid=(t // block_t,),
        in_specs=[
            pl.BlockSpec((block_t,), lambda i: (i,)),
            pl.BlockSpec((block_t,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=INTERPRET if interpret is None else interpret,
    )(x, weight, lo, hi)
    return out[0, 0]
