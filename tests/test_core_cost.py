"""Cost-model tests: the paper's Fig. 5c table, reproduced exactly.

Fig. 5c (k-means, tiles b0 over n points, b1 over k clusters, d untiled):

                 | Fused            | Strip Mined      | Interchanged
  points reads   | n*d              | n*d              | n*d
  centroids reads| n*k*d            | n*k*d            | (n/b0)*k*d
  points chip    | d                | b0*d             | b0*d
  centroids chip | d                | b1*d             | b1*d
  minDist chip   | 2                | 2                | 2*b0
"""
import sys, os

sys.path.insert(0, os.path.dirname(__file__))
from test_core_transforms import mk_kmeans, mk_gemm

from repro.core.cost import traffic
from repro.core.strip_mine import insert_tile_copies, strip_mine, tile

N, K, D, B0, B1 = 48, 8, 5, 8, 4


def _kmeans():
    scatter, *_ = mk_kmeans(N, K, D)
    return scatter


class TestFig5c:
    def test_fused_reads(self):
        r = traffic(_kmeans())  # untransformed: direct accesses only
        assert r.reads["points"] == 2 * N * D  # assign + scatter passes
        assert r.reads["centroids"] == N * K * D

    def test_strip_mined_reads(self):
        t = insert_tile_copies(strip_mine(
            _kmeans(), {"scatter": (B0,), "assign": (B1,)}))
        r = traffic(t)
        assert r.reads["centroids"] == N * K * D
        # points tile loaded once per outer tile (+ once for scatter pass,
        # CSE cannot merge: pre-lift the assign source is per-element)
        assert r.reads["points"] <= 2 * N * D

    def test_interchanged_reads(self):
        t = tile(_kmeans(), {"scatter": (B0,), "assign": (B1,)})
        r = traffic(t)
        # THE headline result: centroids reads drop by a factor of b0
        assert r.reads["centroids"] == (N // B0) * K * D
        assert r.reads["points"] == N * D  # CSE merged both uses

    def test_interchanged_on_chip(self):
        t = tile(_kmeans(), {"scatter": (B0,), "assign": (B1,)})
        r = traffic(t)
        chip = {k.split("#")[0]: v for k, v in r.on_chip.items()}
        assert chip["points_tile"] == B0 * D
        assert chip["centroids_tile"] == B1 * D
        assert chip["assign_stage"] == 2 * B0  # minDistWithInds


def test_gemm_traffic_drops_with_interchange():
    m, n, p = 32, 32, 64
    g = mk_gemm(m, n, p)
    sm = insert_tile_copies(strip_mine(
        g, {"gemm": (8, 8), "kfold": (16,)}))
    ic = tile(g, {"gemm": (8, 8), "kfold": (16,)})
    t_sm, t_ic = traffic(sm), traffic(ic)
    # interchange hoists x/y tiles out of the (i,j) element loops
    assert t_ic.total_reads < t_sm.total_reads
    assert t_ic.reads["x"] == (n // 8) * m * p   # xTile per (ii,jj,kk)
    assert t_ic.reads["y"] == (m // 8) * p * n
