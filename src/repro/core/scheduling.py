"""Metapipeline scheduling (paper §5 "Metapipelining").

For every *strided* pattern in the tiled IR we build a metapipeline
schedule: a topological sort of the body into stages, where each stage
is a tile load, a lifted compute stage, the main inner pattern, or the
tile store.  Every buffer crossing a stage boundary is promoted to a
rotating buffer of configurable ``depth`` (WAR-hazard avoidance
between overlapped outer iterations; depth 2 -- the classic double
buffer -- is the minimum that lets producer and consumer stages
overlap, deeper buffers additionally hide DMA issue latency, see
``cost.metapipeline_time``); hoisted (loop-invariant) loads become a
preload step ("Pipe 0" of Fig. 6) outside the metapipeline.

The schedule also records the paper's two scheduling optimizations:
  * accumulator dedup -- a MultiFold tiled into a nested MultiFold
    keeps a single accumulator (the outer combine consumes the inner
    partial directly, no intermediate output buffer);
  * accumulator forwarding -- when the accumulator cannot fit on-chip
    the stages containing it get a forwarding path (we flag it; the
    Pallas backend realizes it as a revisiting grid).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from . import ir
from .cost import (StageCost, VMEM_BYTES, metapipeline_time,
                   stage_seconds_compute, stage_seconds_load)


@dataclasses.dataclass
class Stage:
    name: str
    kind: str                     # preload | load | compute | body | store
    words: int                    # data moved or buffered
    double_buffered: bool = False
    deps: Tuple[str, ...] = ()
    depth: int = 1                # buffer copies (2 = double buffer)


@dataclasses.dataclass
class Metapipeline:
    pattern: str
    outer_trips: int
    stages: List[Stage]
    preloads: List[Stage]
    fused_accumulator: bool       # accumulator dedup applied
    accumulator_forwarding: bool  # acc does not fit on-chip
    children: List["Metapipeline"]
    depth: int = 2                # stage-crossing buffer depth

    def describe(self, indent: int = 0) -> str:
        pad = "  " * indent
        lines = [f"{pad}Metapipeline[{self.pattern}] x{self.outer_trips}"
                 + (f" depth={self.depth}" if self.depth != 2 else "")
                 + (" (acc-fused)" if self.fused_accumulator else "")
                 + (" (acc-forwarding)" if self.accumulator_forwarding
                    else "")]
        for s in self.preloads:
            lines.append(f"{pad}  Pipe0 preload {s.name} ({s.words} words)")
        for i, s in enumerate(self.stages):
            db = ""
            if s.double_buffered:
                db = " [dbl-buf]" if s.depth == 2 else f" [buf x{s.depth}]"
            lines.append(f"{pad}  Stage{i+1} {s.kind} {s.name}"
                         f" ({s.words} words){db}")
        for c in self.children:
            lines.append(c.describe(indent + 1))
        return "\n".join(lines)


def _acc_words(p: ir.MultiFold) -> int:
    return int(np.prod(p.range_shape)) if p.range_shape else 1


def build_schedule(p: ir.Pattern,
                   vmem_budget_words: int = VMEM_BYTES // 4,
                   depth: int = 2) -> Optional[Metapipeline]:
    """Metapipeline schedule for the outermost strided pattern.

    Parameters
    ----------
    p : tiled (strided) pattern; ``None`` is returned for an untiled
        program (nothing to metapipeline).
    vmem_budget_words : on-chip capacity used for the accumulator-
        forwarding check (an accumulator larger than this gets a
        forwarding path instead of a resident buffer).
    depth : stage-crossing buffer depth.  Every non-hoisted stage
        buffer is annotated with this depth (2 = classic double
        buffer; deeper buffers hide more DMA issue latency at the cost
        of ``depth x`` VMEM, see ``cost.metapipeline_time`` /
        ``memory.plan_memory``).  Hoisted preloads stay single-buffered
        (depth 1).  The DSE (``dse.explore`` / ``dse.explore_pipeline``)
        searches this knob jointly with tile sizes.
    """
    if depth < 2:
        raise ValueError(f"metapipeline depth must be >= 2, got {depth}")
    if not p.strided:
        # descend: the root may be a plain wrapper
        if p.inner is not None:
            return build_schedule(p.inner, vmem_budget_words, depth)
        return None

    preloads: List[Stage] = []
    stages: List[Stage] = []
    children: List[Metapipeline] = []

    # topological order: tensor loads first (no deps), then lifted compute
    # stages (depend on loads), then the body, then the store.
    tensor_loads = [tc for tc in p.loads if isinstance(tc.src, ir.Tensor)]
    stage_loads = [tc for tc in p.loads if isinstance(tc.src, ir.Pattern)]

    for tc in tensor_loads:
        st = Stage(name=tc.name, kind="preload" if tc.hoisted else "load",
                   words=tc.words, double_buffered=not tc.hoisted,
                   depth=1 if tc.hoisted else depth)
        (preloads if tc.hoisted else stages).append(st)

    load_names = tuple(s.name for s in stages if s.kind == "load")
    for tc in stage_loads:
        stages.append(Stage(name=tc.name, kind="compute", words=tc.words,
                            double_buffered=True, deps=load_names,
                            depth=depth))
        sub = build_schedule(tc.src, vmem_budget_words, depth)
        if sub is not None:
            children.append(sub)

    fused_acc = False
    fwd = False
    if p.inner is not None:
        body_words = 0
        if isinstance(p, ir.MultiFold):
            body_words = int(np.prod(p.update_shape)) if p.update_shape else 1
            # accumulator dedup: tiled MultiFold-of-MultiFold emits one
            # accumulator; the outer combine reads the inner partial
            # directly (executor semantics), no intermediate buffer.
            fused_acc = (isinstance(p.inner, ir.MultiFold)
                         and p.combine is not None)
            fwd = _acc_words(p) > vmem_budget_words
        stages.append(Stage(
            name=p.inner.name, kind="body", words=body_words,
            double_buffered=True,
            deps=tuple(s.name for s in stages), depth=depth))
        sub = build_schedule(p.inner, vmem_budget_words, depth)
        if sub is not None:
            children.append(sub)

    out_words = int(np.prod(getattr(p, "range_shape", ()) or ())) or 1
    if isinstance(p, ir.MultiFold) and p.combine is None:
        # write-once tiled Map: stores one output tile per iteration
        stages.append(Stage(name="tile_store", kind="store",
                            words=int(np.prod(p.update_shape)),
                            deps=(stages[-1].name,)))
    elif isinstance(p, (ir.GroupByFold, ir.FlatMap)):
        stages.append(Stage(name="out_store", kind="store", words=out_words,
                            deps=(stages[-1].name,)))

    return Metapipeline(
        pattern=f"{type(p).__name__}:{p.name}", outer_trips=p.trip_count,
        stages=stages, preloads=preloads, fused_accumulator=fused_acc,
        accumulator_forwarding=fwd, children=children, depth=depth)


def model_speedup(mp: Metapipeline, flops_per_body: float,
                  bytes_per_word: int = 4) -> Tuple[float, float, float]:
    """(sequential_s, pipelined_s, speedup) under the two-resource model:
    load/store stages stream at HBM bandwidth, body at peak compute.
    The schedule's buffer ``depth`` feeds the exposed-DMA-latency term
    of ``cost.metapipeline_time``, so the ratio can drop below 1 when
    latency dominates a shallow pipeline (the DSE prices that)."""
    costs = []
    for s in mp.stages:
        if s.kind in ("load", "store"):
            costs.append(StageCost(s.name, s.kind,
                                   stage_seconds_load(s.words,
                                                      bytes_per_word)))
        else:
            costs.append(StageCost(s.name, s.kind,
                                   stage_seconds_compute(flops_per_body)))
    seq, pipe = metapipeline_time(costs, mp.outer_trips, depth=mp.depth)
    return seq, pipe, seq / pipe if pipe > 0 else 1.0
