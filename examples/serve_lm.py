"""Serving example: batched KV-cache decode on three architecture
families (dense GQA, Mamba-2 SSD state, Zamba-2 hybrid).

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import serve

for arch in ("granite-3-2b", "mamba2-370m", "zamba2-2.7b"):
    print(f"== {arch} (reduced config) ==")
    toks = serve(arch, smoke=True, batch=2, prompt_len=16, gen=8)
    print("   sample token ids:", toks[0, :8].reshape(-1)[:8].tolist())
