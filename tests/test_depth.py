"""Metapipeline buffer depth as a searched DSE dimension (ISSUE 6).

Covers the acceptance surface: plan_memory charges ``depth x`` bytes
for stage-crossing buffers, over-deep candidates are pruned at the
VMEM cap, the chosen depth round-trips through the persistent tuning
cache and invalidates on a MODEL_VERSION bump, the pipeline DSE
enumerates and prices at least depths {2, 3, 4}, and a fused pipeline
forced to depth 4 matches the depth-2 megakernel numerically.
"""
import dataclasses
import itertools

import numpy as np
import pytest

from repro.core import dse, ir
from repro.core import pipeline as plmod
from repro.core.cost import (DMA_ISSUE_LATENCY_S, VMEM_BYTES, StageCost,
                             metapipeline_time)
from repro.core.memory import plan_memory
from repro.core.scheduling import build_schedule
from repro.core.strip_mine import tile


# ------------------------------------------------------ memory charging
def test_plan_memory_charges_depth_times_scratch():
    p = dse.gemm_program(512, 512, 512)
    plan = dse.explore(p, cache=False)
    t = tile(p, plan.sizes)
    plans = {d: plan_memory(t, depth=d) for d in (2, 3, 4)}
    base = {b.name: b for b in plans[2].buffers}
    for d in (3, 4):
        for b in plans[d].buffers:
            ref = base[b.name]
            if ref.kind == "double_buffer":
                assert b.depth == d
            else:  # hoisted preloads / caches stay single-copy
                assert b.depth == ref.depth
    # one copy's worth of every rotating buffer: each +1 of depth
    # charges exactly this many extra bytes
    per_copy = sum(b.words * np.dtype(b.dtype).itemsize
                   for b in plans[2].buffers
                   if b.kind == "double_buffer")
    assert per_copy > 0
    for d in (3, 4):
        assert (plans[d].total_bytes
                == plans[2].total_bytes + (d - 2) * per_copy)


def test_plan_memory_rejects_shallow_depth():
    p = dse.gemm_program(256, 256, 256)
    t = tile(p, dse.explore(p, cache=False).sizes)
    with pytest.raises(ValueError, match="depth"):
        plan_memory(t, depth=1)
    with pytest.raises(ValueError, match="depth"):
        build_schedule(t, depth=0)


def test_schedule_carries_depth():
    p = dse.gemm_program(256, 256, 256)
    t = tile(p, dse.explore(p, cache=False).sizes)
    mp = build_schedule(t, depth=3)
    assert mp.depth == 3
    for s in mp.stages:
        if s.double_buffered:
            assert s.depth == 3
    for s in mp.preloads:
        assert s.depth == 1


# ------------------------------------------------------ cost model
def test_deeper_buffering_hides_dma_latency():
    """With tiny stages (step << latency) each extra copy hides one
    step's worth of latency; once (d-1)*step >= latency the term
    saturates and deeper buys nothing."""
    small = [StageCost("ld", "load", 1e-8), StageCost("b", "body", 1e-8)]
    pipes = [metapipeline_time(small, 100, depth=d)[1] for d in (2, 3, 4)]
    assert pipes[0] > pipes[1] > pipes[2]  # still latency-bound

    big_step = DMA_ISSUE_LATENCY_S * 2
    big = [StageCost("ld", "load", big_step),
           StageCost("b", "body", big_step)]
    p2, p3 = (metapipeline_time(big, 100, depth=d)[1] for d in (2, 3))
    assert p2 == p3  # saturated at depth 2: exposure already zero


def test_compute_only_schedule_has_no_exposure():
    costs = [StageCost("b", "body", 1e-8)]
    seq, pipe = metapipeline_time(costs, 10, depth=2)
    assert pipe <= seq


# ------------------------------------------------------ VMEM pruning
def test_deep_candidates_pruned_at_vmem_cap():
    """A budget sized so the best tile fits double- but not quadruple-
    buffered: depth-4 pricing of that tile must return None, and the
    explored plan must still fit."""
    p = dse.gemm_program(2048, 2048, 2048)
    plan = dse.explore(p, cache=False)
    mem2 = plan_memory(tile(p, plan.sizes), depth=2)
    mem4 = plan_memory(tile(p, plan.sizes), depth=4)
    budget = (mem2.total_bytes + mem4.total_bytes) // 2
    assert dse.price(p, plan.sizes, vmem_budget=budget, depth=2)
    assert dse.price(p, plan.sizes, vmem_budget=budget, depth=4) is None
    capped = dse.explore(p, vmem_budget=budget, cache=False)
    assert capped.vmem_bytes <= budget
    assert capped.pruned > 0


# ------------------------------------------------------ cache round-trip
def test_depth_round_trips_through_cache(tmp_path):
    path = str(tmp_path / "dse.json")
    p = dse.attention_program(512, 512, 64)
    plan1 = dse.explore(p, cache=path)
    assert not plan1.cached
    plan2 = dse.explore(p, cache=path)
    assert plan2.cached
    assert plan2.depths == plan1.depths
    assert plan2.depth == plan1.depth

    pipe = dse.filter_fold_pipeline(1 << 14)
    pp1 = dse.explore_pipeline(pipe, cache=path)
    pp2 = dse.explore_pipeline(pipe, cache=path)
    assert pp2.cached
    assert pp2.depths == pp1.depths


def test_cache_invalidates_on_model_version_bump(tmp_path, monkeypatch):
    path = str(tmp_path / "dse.json")
    p = dse.gemm_program(256, 256, 256)
    dse.explore(p, cache=path)
    monkeypatch.setattr(dse, "MODEL_VERSION", dse.MODEL_VERSION + 1)
    plan = dse.explore(p, cache=path)
    assert not plan.cached  # stale pricing must not replay


def test_cache_keys_on_depth_set(tmp_path):
    """A depth-restricted exploration must not be served the full-set
    entry (the key covers the resolved depth tuple)."""
    path = str(tmp_path / "dse.json")
    p = dse.gemm_program(256, 256, 256)
    dse.explore(p, cache=path)
    plan = dse.explore(p, cache=path, depths=(2,))
    assert not plan.cached
    assert plan.depth == 2


# ------------------------------------------------------ pipeline DSE
def test_pipeline_dse_enumerates_depths_234():
    """explore_pipeline prices every (block, depth) pair: the explored
    counter scales with the depth set and the chosen depth lands in
    PipelinePlan.depths."""
    pipe = dse.filter_fold_pipeline(1 << 14)
    base = dse.explore_pipeline(pipe, cache=False, depths=(2,))
    full = dse.explore_pipeline(pipe, cache=False, depths=(2, 3, 4))
    assert full.explored + full.pruned \
        >= 3 * (base.explored + base.pruned)
    assert len(full.depths) == len(full.groups)
    assert all(d in (2, 3, 4) for d in full.depths)


def test_streaming_pipeline_prefers_deeper_buffering():
    """A latency-bound streaming pipeline (tiny per-step tiles) models
    faster with deeper buffers, so the DSE picks a non-default depth."""
    pipe = dse.filter_fold_pipeline(1 << 14)
    full = dse.explore_pipeline(pipe, cache=False)
    shallow = dse.explore_pipeline(pipe, cache=False, depths=(2,))
    assert full.depths[0] > 2
    assert full.modeled_seconds < shallow.modeled_seconds


def test_single_pattern_ties_break_shallow():
    """When depth cannot improve the model (no latency left exposed),
    the rank key must settle on depth 2, not burn VMEM on deeper."""
    p = dse.gemm_program(512, 512, 512)
    plan = dse.explore(p, cache=False)
    pr2 = dse.price(p, plan.sizes, depth=2)
    prb = dse.price(p, plan.sizes, depth=plan.depth)
    if pr2 is not None and prb.modeled_seconds == pr2.modeled_seconds:
        assert plan.depth == 2


# ------------------------------------------------------ numerics
def test_forced_depth4_pipeline_matches_depth2():
    from repro.core.codegen_pallas import lower_fused_pipeline
    from repro.core.measure import synth_inputs

    pipe = dse.filter_fold_pipeline(1 << 12)
    plan = dse.explore_pipeline(pipe, cache=False)
    inputs = synth_inputs(plmod.external_inputs(pipe), seed=0)
    outs = {}
    for d in (2, 4):
        variant = dataclasses.replace(plan,
                                      depths=(d,) * len(plan.groups))
        call = lower_fused_pipeline(pipe, plan=variant)
        assert dict(call.group_lowerings)[
            plmod.output_names(pipe)[-1]] == "megakernel"
        outs[d] = np.asarray(call(**inputs))
    np.testing.assert_allclose(outs[4], outs[2], rtol=1e-6, atol=1e-6)

    ref = np.asarray(plmod.run_unfused(pipe, inputs))
    np.testing.assert_allclose(outs[4], ref, rtol=1e-5, atol=1e-5)


def test_plan_json_round_trip_keeps_depths():
    p = dse.attention_program(256, 256, 64)
    plan = dse.explore(p, cache=False)
    back = dse.TilePlan.from_json(plan.to_json())
    assert back.depths == plan.depths

    pipe = dse.filter_fold_pipeline(1 << 12)
    pp = dse.explore_pipeline(pipe, cache=False)
    ppb = dse.PipelinePlan.from_json(pp.to_json())
    assert ppb.depths == pp.depths
