"""Property-based tests (hypothesis) on the system's invariants.

Invariants under test:
  * tiling is semantics-preserving for EVERY tile size that divides the
    domain, on every pattern type (the paper's core correctness claim);
  * tile-copy traffic never exceeds the untiled streaming traffic for
    sumrows/gemm-like programs (tiling only helps);
  * MultiFold parallel partials == sequential fold (combine/identity);
  * kernels match oracles across random shapes (per-kernel sweeps);
  * data pipeline shards partition the global stream for any world size.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ir
from repro.core.codegen_jax import execute
from repro.core.cost import traffic
from repro.core.strip_mine import tile
from repro.data.pipeline import TokenPipeline

SETTINGS = dict(max_examples=20, deadline=None)


def _divisors(n):
    return [d for d in range(1, n + 1) if n % d == 0]


@st.composite
def map_case(draw):
    d = draw(st.sampled_from([8, 12, 16, 24]))
    b = draw(st.sampled_from(_divisors(d)))
    seed = draw(st.integers(0, 2 ** 16))
    return d, b, seed


@given(map_case())
@settings(**SETTINGS)
def test_map_tiling_preserves_semantics(case):
    d, b, seed = case
    x = ir.Tensor("x", (d,))
    p = ir.Map(domain=(d,), reads=(ir.elem(x),),
               fn=lambda s, e: 3.0 * e + 1.0, name="m")
    t = tile(p, {"m": (b,)})
    xs = np.random.RandomState(seed).randn(d).astype(np.float32)
    # atol guards catastrophic cancellation near 3x+1 == 0
    np.testing.assert_allclose(execute(t, {"x": xs}), 3 * xs + 1,
                               rtol=1e-5, atol=1e-5)


@st.composite
def fold_case(draw):
    m = draw(st.sampled_from([4, 6, 8]))
    n = draw(st.sampled_from([4, 8, 12]))
    bm = draw(st.sampled_from(_divisors(m)))
    bn = draw(st.sampled_from(_divisors(n)))
    seed = draw(st.integers(0, 2 ** 16))
    return m, n, bm, bn, seed


@given(fold_case())
@settings(**SETTINGS)
def test_multifold_tiling_preserves_semantics(case):
    m, n, bm, bn, seed = case
    x = ir.Tensor("x", (m, n))
    p = ir.MultiFold(
        domain=(m, n), range_shape=(m,), init=lambda: jnp.zeros((m,)),
        reads=(ir.elem(x),), out_index_map=lambda i, j: (i,),
        update_shape=(1,), fn=lambda s, acc, e: acc + e,
        combine=lambda a, b: a + b, name="sr")
    t = tile(p, {"sr": (bm, bn)})
    xs = np.random.RandomState(seed).randn(m, n).astype(np.float32)
    np.testing.assert_allclose(execute(t, {"x": xs}), xs.sum(1),
                               rtol=1e-4)


@given(fold_case())
@settings(**SETTINGS)
def test_tiling_never_increases_traffic(case):
    m, n, bm, bn, seed = case
    x = ir.Tensor("x", (m, n))
    p = ir.MultiFold(
        domain=(m, n), range_shape=(m,), init=lambda: jnp.zeros((m,)),
        reads=(ir.elem(x),), out_index_map=lambda i, j: (i,),
        update_shape=(1,), fn=lambda s, acc, e: acc + e,
        combine=lambda a, b: a + b, name="sr")
    base = traffic(p).total_reads
    tiled = traffic(tile(p, {"sr": (bm, bn)})).total_reads
    assert tiled <= base


@given(st.sampled_from([1, 2, 3, 4, 6, 12]), st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_parallel_partials_match_sequential(parts, seed):
    m, n = 12, 8
    x = ir.Tensor("x", (m, n))
    p = ir.MultiFold(
        domain=(m, n), range_shape=(m,), init=lambda: jnp.zeros((m,)),
        reads=(ir.elem(x),), out_index_map=lambda i, j: (i,),
        update_shape=(1,), fn=lambda s, acc, e: acc + e,
        combine=lambda a, b: a + b, name="sr")
    xs = np.random.RandomState(seed).randn(m, n).astype(np.float32)
    seq = execute(p, {"x": xs})
    par = execute(p, {"x": xs}, parallel_partials=parts)
    np.testing.assert_allclose(seq, par, rtol=1e-4)


@st.composite
def groupby_case(draw):
    d = draw(st.sampled_from([16, 32, 48]))
    b = draw(st.sampled_from([d_ for d_ in _divisors(d) if d_ > 1]))
    k = draw(st.sampled_from([2, 4, 8]))
    seed = draw(st.integers(0, 2 ** 16))
    return d, b, k, seed


@given(groupby_case())
@settings(**SETTINGS)
def test_groupbyfold_tiling_preserves_semantics(case):
    d, b, k, seed = case
    x = ir.Tensor("x", (d,))

    def fn(s, e):
        return jnp.clip(jnp.abs(e * 3).astype(jnp.int32), 0, k - 1), e

    p = ir.GroupByFold(domain=(d,), num_keys=k,
                       init=lambda: jnp.zeros(k), reads=(ir.elem(x),),
                       fn=fn, combine=lambda a, b: a + b, name="h")
    xs = np.random.RandomState(seed).randn(d).astype(np.float32)
    np.testing.assert_allclose(
        execute(tile(p, {"h": (b,)}), {"x": xs}),
        execute(p, {"x": xs}), rtol=1e-5)


# --------------------------------------------------------- kernel sweeps
@st.composite
def matmul_shape(draw):
    m = draw(st.sampled_from([16, 32, 64]))
    k = draw(st.sampled_from([16, 32, 64]))
    n = draw(st.sampled_from([16, 32, 64]))
    bm = draw(st.sampled_from(_divisors(m)[-2:]))
    bk = draw(st.sampled_from(_divisors(k)[-2:]))
    bn = draw(st.sampled_from(_divisors(n)[-2:]))
    return m, k, n, bm, bk, bn


@given(matmul_shape())
@settings(max_examples=10, deadline=None)
def test_matmul_kernel_property(shape):
    from repro.kernels import ref
    from repro.kernels.matmul import matmul
    m, k, n, bm, bk, bn = shape
    x = jax.random.normal(jax.random.PRNGKey(m * k), (m, k))
    y = jax.random.normal(jax.random.PRNGKey(k * n + 1), (k, n))
    out = matmul(x, y, block_m=bm, block_k=bk, block_n=bn)
    np.testing.assert_allclose(out, ref.matmul(x, y), rtol=2e-4,
                               atol=2e-4)


@given(st.sampled_from([16, 32, 64]), st.sampled_from([1, 2, 4]),
       st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_flash_attention_property(s, group, seed):
    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention
    hkv, d = 2, 16
    q = jax.random.normal(jax.random.PRNGKey(seed), (1, hkv * group, s, d))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (1, hkv, s, d))
    out = flash_attention(q, k, v, block_q=min(16, s), block_k=min(16, s))
    np.testing.assert_allclose(out, ref.attention(q, k, v), rtol=2e-4,
                               atol=2e-4)


# ------------------------------------------------------------- pipeline
@given(st.sampled_from([1, 2, 4, 8]), st.integers(0, 2 ** 10),
       st.integers(0, 5))
@settings(**SETTINGS)
def test_pipeline_sharding_partition_property(world, seed, step):
    p = TokenPipeline(vocab=97, global_batch=8, seq_len=12, seed=seed)
    full = p.batch_slice(step, 0, 8)["tokens"]
    per = 8 // world
    parts = [p.batch_slice(step, r * per, (r + 1) * per)["tokens"]
             for r in range(world)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)
