"""Zamba2-2.7B [arXiv:2411.15242; hf]: Mamba-2 backbone + shared attn."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    vocab=32000, ssm_state=64, ssm_heads=80, ssm_head_dim=64,
    ssm_conv=4, ssm_expand=2,
    n_heads=32, n_kv_heads=32, head_dim=80, d_ff=10240,
    activation="gelu", shared_attn_every=6)

SMOKE = CONFIG.with_(n_layers=4, d_model=64, vocab=256, ssm_state=16,
                     ssm_heads=4, ssm_head_dim=32, n_heads=4,
                     n_kv_heads=4, head_dim=16, d_ff=128,
                     shared_attn_every=2, remat=False)
