"""Tiled GEMM Pallas kernel -- the paper's Table 3 worked example.

The block structure is exactly the interchanged tiled form the PPL
transformation derives: grid (m/bm, n/bn, p/bk) with the reduction dim
innermost, operand tiles as BlockSpecs (= the xTile/yTile copies), and
an fp32 VMEM accumulator revisited across the reduction grid dim (= the
accumulator-dedup'd MultiFold).  Pallas's grid pipeliner double-buffers
the operand tiles between grid steps -- the metapipeline.

Tile sizes default to MXU-aligned (128); pass ``auto_tile=True`` to let
the PPL cost model pick them via design space exploration
(``repro.core.dse``, cached on disk per (signature, shapes, dtype)).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INTERPRET = True  # CPU container; flip on real TPU


def _auto_blocks(m: int, n: int, k: int,
                 measure: Optional[str] = None, policy=None,
                 options=None) -> Tuple[int, int, int]:
    from .ops import resolve_plan  # shared memoized selector front door
    blocks, _ = resolve_plan("gemm", m, n, k, measure=measure,
                             policy=policy, options=options)
    return blocks


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(x: jax.Array, y: jax.Array, *,
           block_m: int = 128, block_n: int = 128, block_k: int = 128,
           out_dtype: Optional[jnp.dtype] = None,
           auto_tile: bool = False,
           measure: Optional[str] = None, policy=None, options=None,
           interpret: Optional[bool] = None) -> jax.Array:
    """``x @ y`` with explicit VMEM tiling. Shapes must divide blocks.

    ``auto_tile=True`` replaces the block arguments with the DSE-selected
    tile plan for this (m, n, k); ``measure="top_k"`` additionally backs
    the plan with real timings (hybrid DSE, ``core.measure``);
    ``policy`` (a ``core.resilience.Policy``) bounds that measured
    exploration with deadlines, quarantine and plan certification;
    ``options`` (a ``core.dse.Options``) packs any exploration option,
    including ``bucketing=True`` warm starts.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    if auto_tile:
        block_m, block_n, block_k = _auto_blocks(m, n, k, measure,
                                                 policy, options)
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    out_dtype = out_dtype or x.dtype
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)

    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=INTERPRET if interpret is None else interpret,
    )(x, y)
