"""Substrate tests: optimizer, checkpointing, data, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.data.pipeline import TokenPipeline
from repro.optim import adamw
from repro.runtime.fault_tolerance import (HeartbeatMonitor, RescalePlan,
                                           StragglerPolicy, plan_rescale)


# ---------------------------------------------------------------- optim
def _toy_params():
    return {"w": jnp.ones((4, 4), jnp.bfloat16),
            "b": jnp.zeros((4,), jnp.bfloat16)}


def test_adamw_reduces_loss():
    cfg = adamw.AdamWConfig(lr=1e-1, warmup_steps=1, total_steps=50,
                            weight_decay=0.0)
    params = _toy_params()
    state = adamw.init(params, cfg)
    x = jnp.ones((8, 4))
    y = jnp.zeros((8, 4))

    def loss_fn(p):
        return jnp.mean((x @ p["w"].astype(jnp.float32) +
                         p["b"].astype(jnp.float32) - y) ** 2)

    l0 = float(loss_fn(params))
    for _ in range(20):
        g = jax.grad(loss_fn)(params)
        params, state = adamw.update(g, state, params, cfg)
    assert float(loss_fn(params)) < l0 * 0.5


def test_grad_compression_error_feedback():
    """int8 round-trip with error feedback: the residual keeps the
    cumulative update close to uncompressed over many steps."""
    cfg = adamw.AdamWConfig(compress_grads=True)
    g = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 1e-3
    ef = {"g": jnp.zeros((256,))}
    total = jnp.zeros((256,))
    for _ in range(50):
        deq, ef = adamw._compress_with_feedback({"g": g}, ef)
        total = total + deq["g"]
    np.testing.assert_allclose(total / 50, g, atol=float(
        jnp.max(jnp.abs(g))) / 100)


def test_quantize_roundtrip_bounds():
    g = jax.random.normal(jax.random.PRNGKey(1), (1024,))
    q, s = adamw.quantize_int8(g)
    err = jnp.abs(adamw.dequantize_int8(q, s) - g)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


# ----------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    out = ckpt.restore(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(
        np.asarray(out["nested"]["b"], np.float32),
        np.asarray(tree["nested"]["b"], np.float32))


def test_checkpoint_torn_write_skipped(tmp_path):
    tree = {"a": jnp.ones((2,))}
    ckpt.save(str(tmp_path), 1, tree)
    ckpt.save(str(tmp_path), 2, tree)
    # corrupt step-2's manifest (simulated crash mid-write)
    with open(os.path.join(str(tmp_path), "step-2", "manifest.json"),
              "w") as f:
        f.write("{broken")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_async_checkpointer(tmp_path):
    w = ckpt.AsyncCheckpointer(str(tmp_path))
    for s in (5, 10):
        w.save_async(s, {"x": jnp.full((3,), s)})
    w.close()
    assert ckpt.latest_step(str(tmp_path)) == 10
    out = ckpt.restore(str(tmp_path), 10, {"x": jnp.zeros((3,))})
    np.testing.assert_array_equal(out["x"], np.full((3,), 10.0))


# ----------------------------------------------------------------- data
def test_pipeline_determinism():
    p1 = TokenPipeline(vocab=100, global_batch=8, seq_len=16, seed=3)
    p2 = TokenPipeline(vocab=100, global_batch=8, seq_len=16, seed=3)
    for _ in range(3):
        b1, b2 = p1.next_batch(), p2.next_batch()
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_pipeline_shards_partition_global_batch():
    p = TokenPipeline(vocab=100, global_batch=8, seq_len=16, seed=3)
    full = p.batch_slice(0, 0, 8)["tokens"]
    parts = [TokenPipeline(vocab=100, global_batch=8, seq_len=16,
                           seed=3).batch_slice(0, r * 2, (r + 1) * 2)
             ["tokens"] for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)


def test_pipeline_restart_resumes_stream():
    p = TokenPipeline(vocab=50, global_batch=4, seq_len=8, seed=9)
    p.next_batch()
    state = p.state_dict()
    want = p.next_batch()
    p2 = TokenPipeline(vocab=50, global_batch=4, seq_len=8, seed=0)
    p2.load_state_dict(state)
    got = p2.next_batch()
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


# ------------------------------------------------------- fault tolerance
def test_heartbeat_detects_death():
    mon = HeartbeatMonitor(["n0", "n1", "n2"], timeout_s=10.0)
    now = 1000.0
    for n in ("n0", "n1", "n2"):
        mon.heartbeat(n, now=now)
    mon.heartbeat("n0", now=now + 8)
    mon.heartbeat("n1", now=now + 8)
    dead = mon.sweep(now=now + 12)
    assert dead == ["n2"]
    assert sorted(mon.alive()) == ["n0", "n1"]


def test_rescale_preserves_model_parallel():
    # lose one 16-chip node from 256: 240 survivors -> 15 x 16
    plan = plan_rescale(240, model_parallel=16)
    assert plan == RescalePlan(data=15, model=16, dropped=0)
    # catastrophic loss below one model group: degrade mp
    plan = plan_rescale(12, model_parallel=16)
    assert plan.model == 8 and plan.data == 1


def test_straggler_evicted_after_patience():
    pol = StragglerPolicy(threshold=1.5, patience=3)
    evicted = []
    for _ in range(5):
        durations = {f"r{i}": 1.0 for i in range(7)}
        durations["r7"] = 3.0
        evicted = pol.record_step(durations)
    assert evicted == ["r7"]


def test_straggler_transient_blip_not_evicted():
    pol = StragglerPolicy(threshold=1.5, patience=3)
    for step in range(6):
        durations = {f"r{i}": 1.0 for i in range(8)}
        if step == 2:
            durations["r3"] = 4.0  # single blip
        assert pol.record_step(durations) == []


# -------------------------------------------- end-to-end restart drill
def test_train_restart_from_checkpoint(tmp_path):
    """Kill-and-restart drill: train 10 steps with checkpoints, then
    'crash', restart from the checkpoint dir, and confirm the run
    continues from step 10 with identical data and finite loss."""
    from repro.launch.train import train

    d = str(tmp_path)
    losses1, _ = train("granite-3-2b", smoke=True, n_steps=10, batch=2,
                       seq=32, ckpt_dir=d, ckpt_every=5, log_every=100)
    assert ckpt.latest_step(d) == 10
    losses2, _ = train("granite-3-2b", smoke=True, n_steps=14, batch=2,
                       seq=32, ckpt_dir=d, ckpt_every=5, log_every=100)
    assert len(losses2) == 4  # resumed at step 10, ran 4 more
    assert all(np.isfinite(losses2))
