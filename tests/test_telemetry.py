"""Unified telemetry layer (``core.telemetry``): span semantics,
disabled-mode zero-cost guarantees, metrics registry determinism,
thread safety under the background re-tune daemons, Chrome-trace
export, and ``dse.explain`` plan provenance."""
import importlib.util
import json
import os
import threading

import pytest

from repro.core import buckets, dse, resilience, telemetry
from repro.core.options import Options


# ------------------------------------------------------------------ spans


def test_span_nesting_and_attribute_capture():
    telemetry.enable()
    with telemetry.span("outer", a=1) as sp:
        sp.set(b=2)
        with telemetry.span("inner", c=3):
            pass
    log = telemetry.span_log()
    assert [e["name"] for e in log] == ["inner", "outer"]  # exit order
    inner, outer = log
    assert inner["parent"] == "outer"
    assert "parent" not in outer
    assert outer["args"] == {"a": 1, "b": 2}
    assert inner["args"] == {"c": 3}
    # the child's interval nests inside the parent's
    assert inner["ts"] >= outer["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]


def test_span_records_exception():
    telemetry.enable()
    with pytest.raises(ValueError):
        with telemetry.span("boom", stage="x"):
            raise ValueError("nope")
    [e] = telemetry.span_log()
    assert e["args"]["error"] == "ValueError"
    assert e["args"]["stage"] == "x"


def test_disabled_mode_is_a_shared_noop():
    telemetry.disable()
    s1 = telemetry.span("a", x=1)
    s2 = telemetry.span("b")
    # same singleton back every time: zero allocation per site
    assert s1 is s2 is telemetry.NULL_SPAN
    with s1 as sp:
        sp.set(y=2)
    assert telemetry.span_log() == []
    # gated surfaces add zero registry growth when disabled
    telemetry.observe("lat", 0.5)
    telemetry.put_record("plan", "k", {"x": 1})
    snap = telemetry.metrics_snapshot()
    assert snap["histograms"] == {}
    assert snap["spans"] == 0
    assert telemetry.get_record("plan", "k") is None
    # counters/gauges/events stay on: they back always-on stat sinks
    telemetry.count("c")
    telemetry.gauge("g", 2.0)
    telemetry.emit("s", "k", a=1)
    snap = telemetry.metrics_snapshot()
    assert snap["counters"]["c"] == 1
    assert snap["gauges"]["g"] == 2.0
    assert snap["events"] == {"s": 1}


def test_env_enablement_via_options(monkeypatch):
    monkeypatch.setenv("REPRO_TRACE", "1")
    telemetry.reset()
    assert telemetry.enabled()
    monkeypatch.delenv("REPRO_TRACE")
    telemetry.reset()
    assert not telemetry.enabled()
    assert Options(trace=True).resolved().trace is True


# ---------------------------------------------------------------- metrics


def test_log_bounds_deterministic():
    b1 = telemetry.log_bounds(1e-6, 1e2, per_decade=4)
    b2 = telemetry.log_bounds(1e-6, 1e2, per_decade=4)
    assert b1 == b2 == telemetry.LATENCY_BOUNDS_S
    assert b1[0] == pytest.approx(1e-6)
    assert b1[-1] >= 1e2
    assert all(lo < hi for lo, hi in zip(b1, b1[1:]))
    # 4 edges per decade over 8 decades, inclusive endpoints
    assert len(b1) == 33


def test_histogram_bucketing_and_tails():
    telemetry.enable()
    telemetry.observe("h", 1e-9)   # below the lowest edge
    telemetry.observe("h", 1e3)    # above the highest edge
    telemetry.observe("h", 2e-6)
    h = telemetry.metrics_snapshot()["histograms"]["h"]
    assert h["count"] == 3 and sum(h["counts"]) == 3
    assert h["counts"][0] == 1 and h["counts"][-1] == 1
    assert len(h["counts"]) == len(h["bounds"]) + 1
    assert h["sum"] == pytest.approx(1e-9 + 1e3 + 2e-6)


def test_event_stream_filtering():
    telemetry.emit("resilience", "retry", key="a")
    telemetry.emit("resilience", "fallback", key="b")
    telemetry.emit("recovery", "retry", key="c")
    assert len(telemetry.events("resilience")) == 2
    assert telemetry.events("resilience", kind="retry")[0]["key"] == "a"
    telemetry.clear_events("resilience")
    assert telemetry.events("resilience") == []
    assert len(telemetry.events("recovery")) == 1


# ----------------------------------------------------------- thread safety


def test_thread_safety_under_retune_daemons():
    telemetry.enable()
    n = 6

    def _retune():
        with telemetry.span("work"):
            for _ in range(50):
                telemetry.count("t.work")
        return "plan"

    threads = []
    for i in range(n):
        t = buckets.schedule_retune(
            f"tag-{i}", _retune, certify=lambda pl: (True, "ok"),
            promote=lambda pl: None,
            policy=resilience.Policy(timeout_s=0))
        assert t is not None
        threads.append(t)
    # the main thread traces concurrently with the daemons
    for _ in range(50):
        with telemetry.span("main.tick"):
            telemetry.count("t.main")
    buckets.drain()

    snap = telemetry.metrics_snapshot()
    assert snap["counters"]["t.work"] == n * 50
    assert snap["counters"]["t.main"] == 50
    assert snap["counters"]["bucket.promotions"] == n
    log = telemetry.span_log()
    retunes = [e for e in log if e["name"] == "buckets.retune"]
    assert len(retunes) == n
    assert all(e["args"]["outcome"] == "promoted" for e in retunes)
    assert all(e["thread"].startswith("repro-retune-") for e in retunes)
    # nesting is per-thread: each daemon's work span parents correctly
    works = [e for e in log if e["name"] == "work"]
    assert len(works) == n
    assert all(e["parent"] == "buckets.retune" for e in works)
    assert all(e["parent"] != "main.tick" for e in works)


# ---------------------------------------------------------------- export


def _load_check_trace():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "check_trace.py")
    spec = importlib.util.spec_from_file_location("check_trace", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_export_trace_roundtrip(tmp_path):
    telemetry.enable()
    with telemetry.span("dse.explore", pattern="p"):
        with telemetry.span("dse.shortlist"):
            pass
    telemetry.emit("resilience", "retry", key="k")
    out = str(tmp_path / "trace.json")
    telemetry.export_trace(out)
    with open(out) as f:
        doc = json.load(f)
    evs = doc["traceEvents"]
    spans = [e for e in evs if e.get("ph") == "X"]
    assert {e["name"] for e in spans} == {"dse.explore", "dse.shortlist"}
    for e in spans:
        assert e["ts"] >= 0 and e["dur"] >= 0 and e["pid"] == 1
    child = next(e for e in spans if e["name"] == "dse.shortlist")
    assert child["args"]["parent"] == "dse.explore"
    marks = [e for e in evs if e.get("ph") == "i"]
    assert [m["name"] for m in marks] == ["resilience.retry"]
    assert marks[0]["args"]["key"] == "k"
    # timestamps are monotone over the timed events
    ts = [e["ts"] for e in evs if e.get("ph") != "M"]
    assert ts == sorted(ts)
    # and the CI validator agrees
    assert _load_check_trace().validate(doc) == []


def test_check_trace_rejects_bad_traces():
    ct = _load_check_trace()
    assert ct.validate({}) != []
    assert ct.validate({"traceEvents": []}) != []
    # a trace with spans but no dse.explore fails the smoke contract
    doc = {"traceEvents": [{"name": "x", "ph": "X", "ts": 0, "dur": 1}]}
    assert any("dse.explore" in p for p in ct.validate(doc))
    doc = {"traceEvents": [
        {"name": "dse.explore", "ph": "X", "ts": 5.0, "dur": 1.0},
        {"name": "late", "ph": "i", "ts": 1.0}]}
    assert any("monotone" in p for p in ct.validate(doc))


# ------------------------------------------------------------ dse.explain


def test_explain_freshly_explored(tmp_path):
    telemetry.enable()
    plan = dse.explore(dse.filter_reduce_program(1024),
                       options=Options(cache=str(tmp_path / "c.json")))
    d = dse.explain_dict(plan)
    assert d["source"] == "explored"
    prov = d["provenance"]
    assert prov["enumerated"] > 0
    assert set(prov["pruned"]) == {"vmem", "dominated",
                                   "measure_failures"}
    assert prov["analytic_ranks"]
    text = dse.explain(plan)
    assert "source: explored" in text
    assert "pruned by reason" in text
    assert "analytic ranks" in text


def test_explain_cached(tmp_path):
    telemetry.enable()
    opts = Options(cache=str(tmp_path / "c.json"))
    p = dse.filter_reduce_program(1024)
    dse.explore(p, options=opts)
    plan = dse.explore(p, options=opts)
    assert plan.cached
    d = dse.explain_dict(plan)
    assert d["source"] == "cache"
    assert "source: cache" in dse.explain(plan)


def test_explain_warm_started(tmp_path):
    telemetry.enable()
    opts = Options(cache=str(tmp_path / "c.json"), bucketing=True)
    dse.explore(dse.attention_program(256, 256, 64), options=opts)
    plan = dse.explore(dse.attention_program(192, 256, 64), options=opts)
    buckets.drain()
    assert plan.warm_start
    d = dse.explain_dict(plan)
    assert d["source"] == "warm_start"
    assert d["provenance"]["retune_tag"].startswith("tile|")
    assert f"(bucket {plan.bucket})" in dse.explain(plan)


def test_explain_without_tracing(tmp_path):
    telemetry.disable()
    plan = dse.explore(dse.filter_reduce_program(512),
                       options=Options(cache=False))
    d = dse.explain_dict(plan)
    assert d["source"] == "explored"
    assert "provenance" not in d
    assert "REPRO_TRACE=1" in dse.explain(plan)
