"""Flash attention (GQA, causal, optional sliding window) in Pallas.

This kernel is the paper's method applied to attention (DESIGN.md §4):
strip-mine the softmax MultiFold over keys, interchange it with the
query Map, and keep a running (max, sum, acc) accumulator forwarded
between the strided iterations -- the paper's "accumulator forwarding"
metapipeline optimization *is* online softmax.

Grid: (batch*kv_head, q_group, q_blocks, kv_blocks), kv innermost so the
running statistics live in VMEM scratch across kv steps.  Sliding-window
(Mixtral SWA) and causal masks are applied from block coordinates.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INTERPRET = True
NEG_INF = -1e30


def _auto_blocks(sq: int, sk: int, d: int,
                 measure: Optional[str] = None, policy=None,
                 options=None) -> tuple:
    from .ops import resolve_plan  # shared memoized selector front door
    blocks, _ = resolve_plan("attention", sq, sk, d, measure=measure,
                             policy=policy, options=options)
    return blocks


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: Optional[int],
               n_kv: int, block_q: int, block_k: int, q_offset: int):
    kv_i = pl.program_id(3)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                   # (block_q, d)
    k = k_ref[0, 0]                   # (block_k, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = (pl.program_id(2) * block_q + jax.lax.iota(jnp.int32, block_q)
            + q_offset)[:, None]
    kpos = (kv_i * block_k + jax.lax.iota(jnp.int32, block_k))[None, :]
    mask = jnp.ones(s.shape, jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jnp.dot(p.astype(v_ref.dtype), v_ref[0, 0],
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(kv_i == n_kv - 1)
    def _done():
        denom = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    auto_tile: bool = False,
                    measure: Optional[str] = None, policy=None,
                    options=None,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) -> (B, Hq, Sq, D).

    GQA: the q-head group dim is folded into the grid so each kv head's
    K/V tiles are loaded once per group member (reuse via grid order).
    ``auto_tile=True`` picks (block_q, block_k) by DSE on the attention
    proxy program (``repro.core.dse.attention_program``); ``policy``
    (a ``core.resilience.Policy``) bounds any measured exploration.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    if auto_tile:
        block_q, block_k = _auto_blocks(sq, sk, d, measure, policy,
                                        options)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    n_q, n_kv = sq // block_q, sk // block_k
    q_offset = sk - sq  # decode/prefix: queries sit at the sequence tail

    qg = q.reshape(b * hkv, group, sq, d)
    kg = k.reshape(b * hkv, 1, sk, d)
    vg = v.reshape(b * hkv, 1, sk, d)
    grid = (b * hkv, group, n_q, n_kv)

    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          window=window, n_kv=n_kv, block_q=block_q,
                          block_k=block_k, q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bh, g, qi, ki: (bh, g, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bh, g, qi, ki: (bh, 0, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bh, g, qi, ki: (bh, 0, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bh, g, qi, ki: (bh, g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, group, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=INTERPRET if interpret is None else interpret,
    )(qg, kg, vg)
    return out.reshape(b, hq, sq, d)
