"""Deterministic synthetic token pipeline, sharded by data-parallel rank.

Properties a 1000-node run needs, all tested:

  * determinism: batch(step) is a pure function of (seed, step) -- a
    restarted/rescheduled job resumes mid-stream with no drift;
  * shard locality: each data-parallel rank materializes only its slice
    (host RAM stays O(local batch), not O(global batch));
  * restart: ``state_dict``/``load_state_dict`` capture the cursor.

The generator is a counter-mode hash (splitmix64 over (seed, step,
position)) so any (rank, step) slice is O(1) addressable -- the same
property real deployments get from deterministic tfrecord sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    n_codebooks: int = 0
    step: int = 0

    def batch_slice(self, step: int, lo: int, hi: int) -> Dict:
        """Rows [lo, hi) of the global batch at ``step`` -- each rank
        calls this with its own slice only."""
        rows = hi - lo
        cb = max(1, self.n_codebooks)
        idx = (np.uint64(self.seed) * np.uint64(0x100000001B3)
               + np.uint64(step) * np.uint64(1 << 40))
        pos = (np.arange(lo * self.seq_len * cb, hi * self.seq_len * cb,
                         dtype=np.uint64) + idx)
        toks = (_splitmix64(pos) % np.uint64(self.vocab)).astype(np.int32)
        if self.n_codebooks:
            toks = toks.reshape(rows, self.seq_len, cb)
        else:
            toks = toks.reshape(rows, self.seq_len)
        labels = np.roll(toks, -1, axis=1)
        return {"tokens": toks, "labels": labels}

    def next_batch(self, rank: int = 0, world: int = 1) -> Dict:
        assert self.global_batch % world == 0
        per = self.global_batch // world
        out = self.batch_slice(self.step, rank * per, (rank + 1) * per)
        self.step += 1
        return out

    def state_dict(self) -> Dict:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, state: Dict) -> None:
        self.step = int(state["step"])
        self.seed = int(state["seed"])
