"""The cost-model calibration subsystem (repro.core.calibrate).

Covers the ISSUE-5 acceptance surface: the least-squares fit is
deterministic (same samples -> bit-for-bit identical coefficients),
recovers planted coefficients, falls back to the rank-preserving
bandwidth rescale when the affine model orders candidates worse, and
profiles persist / invalidate keyed by device + cost-model revision.
"""
import pytest

from repro.core import calibrate, dse
from repro.core.cost import HBM_BYTES_PER_S


def _samples():
    """Fixed synthetic ledger: two workloads, planted coefficients
    s_per_byte=2e-9 (500 GB/s effective), overhead 3us/step (MultiFold)
    and 7us/step (Pipeline)."""
    s, o_mf, o_pl = 2e-9, 3e-6, 7e-6
    out = []
    for i, (b, st) in enumerate([(1e6, 8), (2e6, 4), (4e6, 2)]):
        out.append(calibrate.Sample(
            workload="w1", kind="MultiFold", stream_bytes=b, steps=st,
            measured_s=s * b + o_mf * st, key=f"w1/{i}"))
    for i, (b, st) in enumerate([(5e5, 16), (1e6, 8), (8e6, 1)]):
        out.append(calibrate.Sample(
            workload="w2", kind="Pipeline", stream_bytes=b, steps=st,
            measured_s=s * b + o_pl * st, key=f"w2/{i}"))
    return out


def test_fit_recovers_planted_coefficients():
    prof = calibrate.fit(_samples(), device="testdev")
    assert prof.mode == "affine"
    assert abs(prof.s_per_byte - 2e-9) / 2e-9 < 1e-6
    assert abs(prof.overhead_s["MultiFold"] - 3e-6) / 3e-6 < 1e-6
    assert abs(prof.overhead_s["Pipeline"] - 7e-6) / 7e-6 < 1e-6
    assert prof.mean_abs_err_s < 1e-9
    assert prof.n_samples == 6


def test_fit_is_deterministic_bit_for_bit():
    a = calibrate.fit(_samples(), device="testdev")
    b = calibrate.fit(list(reversed(_samples())), device="testdev")
    # same sample *set* -> identical floats, not merely close ones:
    # cached plans and CI cache keys hash these exact values
    assert a.s_per_byte.hex() == b.s_per_byte.hex()
    for k in a.overhead_s:
        assert a.overhead_s[k].hex() == b.overhead_s[k].hex()
    assert a.hash == b.hash


def test_fit_negative_bandwidth_falls_back_to_scale():
    """Measured times *decreasing* in bytes would fit a negative
    bandwidth; the guard keeps the profile physical and
    rank-preserving."""
    samples = [calibrate.Sample(
        workload="w", kind="Map", stream_bytes=b, steps=1,
        measured_s=m, key=f"k{b}")
        for b, m in [(1e6, 3e-3), (2e6, 2e-3), (4e6, 1e-3)]]
    prof = calibrate.fit(samples, device="testdev")
    assert prof.mode == "scale"
    assert prof.s_per_byte > 0
    assert all(v == 0.0 for v in prof.overhead_s.values())


def test_fit_empty_raises():
    with pytest.raises(ValueError):
        calibrate.fit([])


def test_predicted_seconds_uncalibrated_is_datasheet():
    assert calibrate.predicted_seconds("Map", 819e9) \
        == pytest.approx(819e9 / HBM_BYTES_PER_S)
    prof = calibrate.fit(_samples(), device="testdev")
    got = calibrate.predicted_seconds("MultiFold", 1e6, 8, profile=prof)
    assert got == pytest.approx(2e-9 * 1e6 + 3e-6 * 8, rel=1e-5)
    # unknown pattern kind: bandwidth term only, no invented overhead
    assert calibrate.predicted_seconds("Unknown", 1e6, 8, profile=prof) \
        == pytest.approx(prof.s_per_byte * 1e6, rel=1e-6)


def test_observe_roundtrip_and_hash_tracking():
    assert calibrate.load_profile() is None
    assert calibrate.active_profile_hash() == calibrate.UNCALIBRATED

    prof = calibrate.observe(_samples())
    assert calibrate.active_profile_hash() == prof.hash
    loaded = calibrate.load_profile()
    assert loaded is not None
    assert loaded.s_per_byte == prof.s_per_byte
    assert loaded.overhead_s == prof.overhead_s

    # observing identical samples dedupes: profile (and hash) stable
    again = calibrate.observe(_samples())
    assert again.n_samples == prof.n_samples
    assert again.hash == prof.hash

    # new evidence -> new profile -> new hash (DSE cache keys roll over)
    extra = calibrate.Sample(workload="w3", kind="Map",
                             stream_bytes=3e6, steps=2,
                             measured_s=9e-3, key="w3/0")
    updated = calibrate.observe([extra])
    assert updated.n_samples == prof.n_samples + 1
    assert calibrate.active_profile_hash() == updated.hash != prof.hash


def test_profile_for_other_device_or_model_version_ignored(tmp_path):
    path = str(tmp_path / "prof.json")
    calibrate.observe(_samples(), device="devA", path=path)
    assert calibrate.load_profile("devA", path=path) is not None
    assert calibrate.load_profile("devB", path=path) is None

    stale = calibrate.fit(_samples(), device="devA",
                          model_version=dse.MODEL_VERSION - 1)
    import json
    with open(path, "w") as f:
        json.dump({"profile": stale.to_json(), "samples": []}, f)
    assert calibrate.load_profile("devA", path=path) is None


def test_fit_weights_small_workloads_fairly():
    """A 90 ms workload must not flatten a 500 us workload's
    coefficients: after the relative-error weighting, the small
    workload's in-sample ranking must be preserved too."""
    big = [calibrate.Sample(
        workload="big", kind="MultiFold", stream_bytes=b, steps=st,
        measured_s=2e-9 * b + 1e-4 * st, key=f"b{st}")
        for b, st in [(1e9, 8), (2e9, 4), (4e9, 2)]]
    small = [calibrate.Sample(
        workload="small", kind="Pipeline", stream_bytes=1e5, steps=st,
        measured_s=2e-9 * 1e5 + 5e-5 * st, key=f"s{st}")
        for st in (16, 8, 4, 2)]
    prof = calibrate.fit(big + small, device="testdev")
    pred = [calibrate.predicted_seconds("Pipeline", s.stream_bytes,
                                        s.steps, profile=prof)
            for s in small]
    meas = [s.measured_s for s in small]
    from repro.core.measure import spearman
    assert spearman(pred, meas) == 1.0
