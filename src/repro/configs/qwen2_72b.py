"""Qwen2-72B [arXiv:2407.10671; hf]: dense GQA with QKV bias."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    fsdp=True,  # params exceed per-chip HBM at TP=16: ZeRO-3 shard
    name="qwen2-72b", family="dense", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=29568, vocab=152064,
    activation="swiglu", qkv_bias=True, rope_theta=1e6)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     head_dim=16, d_ff=128, vocab=256, remat=False)
