"""Hardware generation: lower tiled PPL IR to Pallas TPU kernels.

This is the paper's §5 code generation step with TPU templates in place
of MaxJ templates (see Table 4 mapping in DESIGN.md):

  * the outer strided pattern's domain      -> ``pallas_call`` grid
  * each TileCopy                           -> ``pl.BlockSpec(tile_shape,
                                               index_map)`` (HBM->VMEM DMA)
  * double buffers between metapipe stages  -> Pallas grid pipelining
    (the Mosaic pipeliner double-buffers every BlockSpec operand between
    grid steps -- exactly the paper's metapipeline semantics)
  * Map over scalars (Vector template)      -> vectorized body on the tile
  * MultiFold over scalars (Reduction tree) -> ``jnp.dot``/``jnp.sum`` (MXU)
  * GroupByFold (CAM template)              -> one-hot matmul accumulation
    into a revisited output block (sequential TPU grid)
  * FlatMap (Parallel FIFO template)        -> masked prefix-sum compaction
    at a dynamic offset carried in SMEM scratch across grid steps
  * fused pipeline DAG (``lower_fused_dag``)-> one multi-output kernel:
    producer stages in VMEM scratch, fold/CAM terminals revisit their
    accumulator block, Map terminals stream a write-once output block
    per grid step (never revisited)

Kernels are validated in ``interpret=True`` mode against the
``codegen_jax`` oracle; TPU (MXU/VMEM alignment) is the codegen target.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ir, resilience, telemetry
from .affine import AffineMap

INTERPRET = True  # container is CPU-only; flip on real TPU


def _call_map(amap: "AffineMap", stack: Tuple) -> Tuple:
    """Call an AffineMap with a kernel-local stack: pad absent leading
    (enclosing) indices with zeros, or drop leading entries the map has
    zero columns for anyway (local maps ignore grid dims)."""
    n = amap.n_in
    if len(stack) == n:
        return amap(*stack)
    if len(stack) < n:
        return amap(*((0,) * (n - len(stack)) + tuple(stack)))
    return amap(*stack[len(stack) - n:])


def _block_index_map(copy_map: AffineMap, tile_shape: Tuple[int, ...],
                     grid_rank: int) -> Callable:
    """BlockSpec index maps return *block* indices: element base / tile.

    The copy's element-level map must address whole blocks: every base
    offset and every grid stride has to be a multiple of the tile
    extent in that dimension, or the ``elem // tile`` division below
    silently lands the DMA on the wrong block.
    """
    for d_out in range(copy_map.n_out):
        base = copy_map.base[d_out]
        if base % tile_shape[d_out] != 0:
            raise ValueError(
                f"tile copy base {copy_map.base} is not block-aligned: "
                f"dim {d_out} offset {base} is not a multiple of tile "
                f"extent {tile_shape[d_out]} (tile {tile_shape}); "
                "BlockSpec index maps address whole blocks")
        for d_in in range(copy_map.n_in):
            s = copy_map.mat[d_out][d_in]
            if s % tile_shape[d_out] != 0:
                raise ValueError(
                    f"tile copy stride {s} (out dim {d_out}, grid dim "
                    f"{d_in}) is not a multiple of tile extent "
                    f"{tile_shape[d_out]} (tile {tile_shape}); the "
                    "grid would address partial blocks")

    def imap(*grid_idx):
        full = tuple(grid_idx) + (0,) * (copy_map.n_in - len(grid_idx))
        elem = copy_map(*full[:copy_map.n_in])
        return tuple(e // t for e, t in zip(elem, tile_shape))

    return imap


def _gather_window(tile, amap, window: Tuple[int, ...], stack):
    """Slice one access window out of an on-chip tile at the given index
    stack (singleton dims squeezed, matching the oracle's semantics)."""
    starts = _call_map(amap, tuple(stack))
    starts = tuple(jnp.asarray(s, jnp.int32) for s in starts[-tile.ndim:])
    return jnp.squeeze(jax.lax.dynamic_slice(tile, starts, window))


def _vmapped_tile_fn(inner: ir.Map, n_reads: int) -> Callable:
    """Vector template: apply the Map's scalar fn across the whole tile.

    Reads must be tile-local (AffineMap with zero base).  Returns
    f(grid_idx, *tiles) -> tile of inner.shape.
    """
    dom = inner.domain

    def run(grid_idx, *tiles):
        def body(flat):
            idx = []
            rem = flat
            for e in reversed(dom):
                idx.append(rem % e)
                rem = rem // e
            idx = tuple(reversed(idx))
            stack = tuple(grid_idx) + idx
            wins = [_gather_window(t, a.index_map, a.window, stack)
                    for t, a in zip(tiles, inner.reads)]
            return inner.fn(stack, *wins)

        n = int(np.prod(dom))
        vals = jax.vmap(body)(jnp.arange(n, dtype=jnp.int32))
        return vals.reshape(tuple(dom) + vals.shape[1:])

    return run


# --------------------------------------------------------------------
# Tiled Map: MultiFold(grid) write-once { loads; Map(tile) }
# --------------------------------------------------------------------


def lower_tiled_map(p: ir.MultiFold) -> Callable:
    assert p.strided and p.combine is None and isinstance(p.inner, ir.Map)
    inner = p.inner
    grid = tuple(p.domain)
    loads = [tc for tc in p.loads if isinstance(tc.src, ir.Tensor)]
    assert len(loads) == len(inner.reads), "all reads must be tiled"
    tile_fn = _vmapped_tile_fn(inner, len(loads))

    in_specs = [
        pl.BlockSpec(tc.tile_shape,
                     _block_index_map(tc.index_map, tc.tile_shape,
                                      len(grid)))
        for tc in loads
    ]
    out_tile = tuple(p.update_shape)
    out_map = AffineMap.probe(lambda *g: p.out_index_map(*g), len(grid))
    out_spec = pl.BlockSpec(out_tile,
                            _block_index_map(out_map, out_tile, len(grid)))

    def kernel(*refs):
        *ins, out = refs
        gidx = tuple(pl.program_id(i) for i in range(len(grid)))
        out[...] = tile_fn(gidx, *[r[...] for r in ins]).astype(out.dtype)

    order = {tc.uid: i for i, tc in enumerate(loads)}

    def call(**tensors):
        args = [jnp.asarray(tensors[tc.src.name]) for tc in loads]
        return pl.pallas_call(
            kernel, grid=grid, in_specs=in_specs, out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct(tuple(p.range_shape),
                                           jnp.dtype(p.dtype)),
            interpret=INTERPRET)(*args)

    return call


# --------------------------------------------------------------------
# Tiled GEMM (Table 3 interchanged form):
#   MultiFold(gi,gj) write-once { MultiFold(kk) fold { Map(bi,bj){fold} } }
# --------------------------------------------------------------------


def match_tiled_gemm(p: ir.Pattern) -> bool:
    return (isinstance(p, ir.MultiFold) and p.strided and p.combine is None
            and isinstance(p.inner, ir.MultiFold) and p.inner.strided
            and p.inner.is_fold and isinstance(p.inner.inner, ir.Map))


def lower_tiled_gemm(p: ir.MultiFold) -> Callable:
    """MXU template: the inner Map{fold} is a tile matmul; the strided
    fold revisits the output block across the reduction grid dim."""
    assert match_tiled_gemm(p)
    f = p.inner
    gi, gj = p.domain
    (kk,) = f.domain
    loads = [tc for tc in f.loads if isinstance(tc.src, ir.Tensor)]
    assert len(loads) == 2, "gemm expects two tiled operands"
    # operand order from the leaf fold's reads: [0] -> x (bi, bk) indexed
    # (i, k); [1] -> y (bk, bj) indexed (k, j)  (paper Table 3 layout)
    leaf = f.inner.inner
    assert isinstance(leaf, ir.MultiFold) and len(leaf.reads) == 2
    x_tc = leaf.reads[0].src
    y_tc = leaf.reads[1].src
    assert x_tc in loads and y_tc in loads
    bi, bj = f.range_shape
    bk = x_tc.tile_shape[1]
    assert x_tc.tile_shape == (bi, bk) and y_tc.tile_shape == (bk, bj)

    grid = (gi, gj, kk)  # reduction dim innermost: output block revisited
    in_specs = [
        pl.BlockSpec((bi, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bj), lambda i, j, k: (k, j)),
    ]
    out_spec = pl.BlockSpec((bi, bj), lambda i, j, k: (i, j))

    def kernel(x_ref, y_ref, o_ref):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(
            x_ref[...], y_ref[...],
            preferred_element_type=o_ref.dtype)  # MXU reduction tree

    def call(**tensors):
        x = jnp.asarray(tensors[x_tc.src.name])
        y = jnp.asarray(tensors[y_tc.src.name])
        return pl.pallas_call(
            kernel, grid=grid, in_specs=in_specs, out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct(tuple(p.range_shape),
                                           jnp.dtype(p.dtype)),
            interpret=INTERPRET)(x, y)

    return call


# --------------------------------------------------------------------
# Tiled GroupByFold: GroupByFold(grid){ loads; GroupByFold(tile) }
# --------------------------------------------------------------------


def lower_tiled_groupby(p: ir.GroupByFold,
                        combine_is_add: bool = True) -> Callable:
    """CAM template: dense one-hot accumulation.  The output block is
    revisited on every grid step (constant index map); the TPU grid is
    sequential so accumulation across steps is well defined."""
    assert p.strided and isinstance(p.inner, ir.GroupByFold)
    inner = p.inner
    (g,) = p.domain
    (b,) = inner.domain
    loads = [tc for tc in p.loads if isinstance(tc.src, ir.Tensor)]
    assert len(loads) == len(inner.reads)
    elem = tuple(p.elem_shape)
    k = p.num_keys
    ew = int(np.prod(elem)) if elem else 1

    in_specs = [
        pl.BlockSpec(tc.tile_shape,
                     _block_index_map(tc.index_map, tc.tile_shape, 1))
        for tc in loads
    ]
    out_shape = (k,) + elem
    out_spec = pl.BlockSpec(out_shape, lambda i: (0,) * (1 + len(elem)))

    def kernel(*refs):
        *ins, out = refs
        gi = pl.program_id(0)

        @pl.when(gi == 0)
        def _init():
            out[...] = jnp.asarray(p.init(), out.dtype)

        tiles = [r[...] for r in ins]

        def body(l):
            stack = (gi, l)
            wins = []
            for t, a in zip(tiles, inner.reads):
                starts = _call_map(a.index_map, stack)
                starts = tuple(jnp.asarray(s, jnp.int32)
                               for s in starts[-t.ndim:])
                wins.append(jnp.squeeze(
                    jax.lax.dynamic_slice(t, starts, a.window)))
            return inner.fn(stack, *wins)

        keys, vals = jax.vmap(body)(jnp.arange(b, dtype=jnp.int32))
        onehot = jax.nn.one_hot(keys, k, dtype=out.dtype)       # (b, k)
        vals2 = jnp.asarray(vals, out.dtype).reshape(b, ew)     # (b, ew)
        upd = jnp.dot(onehot.T, vals2)                          # MXU scatter
        out[...] += upd.reshape(out_shape)

    def call(**tensors):
        args = [jnp.asarray(tensors[tc.src.name]) for tc in loads]
        return pl.pallas_call(
            kernel, grid=(g,), in_specs=in_specs, out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct(out_shape, jnp.dtype(p.dtype)),
            interpret=INTERPRET)(*args)

    return call


# --------------------------------------------------------------------
# Tiled FlatMap: FlatMap(grid){ loads; FlatMap(tile) }
# --------------------------------------------------------------------


def lower_tiled_flatmap(p: ir.FlatMap) -> Callable:
    """Parallel-FIFO template: per-tile mask + prefix-sum compaction,
    appended at a dynamic offset carried in SMEM across grid steps."""
    assert p.strided and isinstance(p.inner, ir.FlatMap)
    inner = p.inner
    (g,) = p.domain
    (b,) = inner.domain
    m = inner.max_per_iter
    cap_tile = b * m
    cap = g * cap_tile
    loads = [tc for tc in p.loads if isinstance(tc.src, ir.Tensor)]
    assert len(loads) == len(inner.reads)

    in_specs = [
        pl.BlockSpec(tc.tile_shape,
                     _block_index_map(tc.index_map, tc.tile_shape, 1))
        for tc in loads
    ]
    out_specs = [
        pl.BlockSpec((cap,), lambda i: (0,)),   # FIFO buffer (revisited)
        pl.BlockSpec((1,), lambda i: (0,)),     # total count
    ]

    def kernel(*refs):
        *ins, buf, cnt = refs
        gi = pl.program_id(0)

        @pl.when(gi == 0)
        def _init():
            buf[...] = jnp.zeros_like(buf)
            cnt[...] = jnp.zeros_like(cnt)

        tiles = [r[...] for r in ins]

        def body(l):
            stack = (gi, l)
            wins = []
            for t, a in zip(tiles, inner.reads):
                starts = _call_map(a.index_map, stack)
                starts = tuple(jnp.asarray(s, jnp.int32)
                               for s in starts[-t.ndim:])
                wins.append(jnp.squeeze(
                    jax.lax.dynamic_slice(t, starts, a.window)))
            return inner.fn(stack, *wins)

        vals, cnts = jax.vmap(body)(jnp.arange(b, dtype=jnp.int32))
        vals = vals.reshape(b * m)
        lane = jnp.arange(m)[None, :]
        valid = (lane < cnts[:, None]).reshape(b * m)
        # intra-tile prefix-sum compaction (the "parallel FIFO" fill)
        pos = jnp.cumsum(valid) - 1
        local_n = valid.sum().astype(jnp.int32)
        compact = jnp.zeros((cap_tile,), vals.dtype)
        compact = compact.at[jnp.where(valid, pos, cap_tile - 1)].set(
            jnp.where(valid, vals, compact[cap_tile - 1]), mode="drop")
        base = cnt[0]
        window = jax.lax.dynamic_slice(buf[...], (base,), (cap_tile,))
        take = jnp.arange(cap_tile) < local_n
        merged = jnp.where(take, compact, window)
        buf[...] = jax.lax.dynamic_update_slice(buf[...], merged, (base,))
        cnt[0] = base + local_n

    def call(**tensors):
        args = [jnp.asarray(tensors[tc.src.name]) for tc in loads]
        buf, cnt = pl.pallas_call(
            kernel, grid=(g,), in_specs=in_specs, out_specs=out_specs,
            out_shape=[
                jax.ShapeDtypeStruct((cap,), jnp.dtype(p.dtype)),
                jax.ShapeDtypeStruct((1,), jnp.int32),
            ],
            interpret=INTERPRET)(*args)
        return buf, cnt[0]

    return call


# --------------------------------------------------------------------
# Fused pipelines: megakernel with VMEM-resident stage intermediates
# --------------------------------------------------------------------


def _read_tiles(reads, env: Dict[str, Any], stack):
    """Resolve a pattern's reads against in-kernel buffers keyed by the
    TileCopy uid (input blocks and VMEM stage scratch alike)."""
    wins = []
    for a in reads:
        if not isinstance(a.src, ir.TileCopy):
            raise NotImplementedError(
                f"fused chain: read of {type(a.src).__name__} left in "
                "place (expected every source tiled into VMEM)")
        wins.append(_gather_window(env[a.src.uid], a.index_map,
                                   a.window, stack))
    return wins


def _collect_dag_loads(terminals):
    """Union the terminal trees' root loads for one kernel.

    Tensor tile copies dedupe by ``fusion.tile_copy_key`` (two terminal
    trees reading the same tile carry distinct uids for the same DMA)
    -- each group becomes ONE BlockSpec operand whose value binds every
    member uid.  Producer stages dedupe by uid (``fuse_dag_stages``
    already shares the TileCopy across consumers); first-appearance
    order is topological because each terminal's stage list is a
    topologically closed prefix-consistent sequence.
    """
    from .fusion import tile_copy_key

    tensor_groups: List[Tuple[Any, List[ir.TileCopy]]] = []
    by_key: Dict[Any, List[ir.TileCopy]] = {}
    stage_loads: List[ir.TileCopy] = []
    stage_seen = set()
    for _, t in terminals:
        for tc in t.loads:
            if isinstance(tc.src, ir.Tensor):
                key = tile_copy_key(tc)
                if key not in by_key:
                    by_key[key] = []
                    tensor_groups.append((key, by_key[key]))
                by_key[key].append(tc)
            else:
                if tc.uid in stage_seen:
                    continue
                stage_seen.add(tc.uid)
                stage_loads.append(tc)
    return tensor_groups, stage_loads


def _terminal_emitter(p: ir.Pattern):
    """Template selection for one fused-DAG terminal.

    Returns ``(out_full, out_shape, spec, emit)``: the padded full
    output array shape, the logical shape to reshape results to, the
    output BlockSpec, and ``emit(g, out, env)`` which updates the
    terminal's output block at grid step ``g``:

      * fold terminal       -> revisited accumulator block (init at
                               g == 0, partial fold merged via combine)
      * keyed-fold terminal -> CAM template, one-hot MXU scatter into a
                               revisited dense block
      * Map terminal        -> write-once streaming template: the tile
                               computed this step IS output block ``g``;
                               no init, no revisit, no accumulator
    """
    q = p.inner
    if q is None:
        raise NotImplementedError("fused terminal: tiled body expected")
    (b,) = q.domain

    if isinstance(p, ir.MultiFold) and p.combine is None:
        # write-once tiled Map (the paper's "(_)"): out block g streams
        if not isinstance(q, ir.Map):
            raise NotImplementedError(
                "fused chain: write-once terminal must wrap a Map tile")
        elem = tuple(q.elem_shape)
        if len(elem) > 1:
            raise NotImplementedError(
                "Map terminals stream blocks of rank <= 2")
        out_block = (b,) + (elem if elem else (1,))
        out_shape = tuple(p.range_shape)            # (n,) + elem
        out_full = (out_shape[0],) + (elem if elem else (1,))
        tile_fn = _stage_tile_fn(q)

        def emit_map(g, out, env):
            tile = tile_fn((g,), env)
            out[...] = jnp.asarray(tile, out.dtype).reshape(out_block)

        spec = pl.BlockSpec(
            out_block, lambda g: (g,) + (0,) * (len(out_block) - 1))
        return out_full, out_shape, spec, emit_map

    if isinstance(p, ir.MultiFold):
        # terminal fold: revisited accumulator block, inner partial
        # folded from the combine identity then merged (executor
        # semantics; accumulator dedup keeps this single block).
        if not isinstance(q, ir.MultiFold) or not q.is_fold:
            raise NotImplementedError(
                "fused chain terminal must be a fold (update covers the "
                "whole accumulator)")
        range_shape = tuple(p.range_shape)
        out_block = _padded_out(range_shape)
        if len(range_shape) > 2:
            raise NotImplementedError("fold accumulators of rank <= 2")

        def emit_fold(g, out, env):
            @pl.when(g == 0)
            def _init():
                out[...] = jnp.asarray(p.init(), out.dtype
                                       ).reshape(out_block)

            def body(l, acc):
                stack = (g, l)
                wins = _read_tiles(q.reads, env, stack)
                return jnp.asarray(q.fn(stack, acc, *wins),
                                   acc.dtype).reshape(acc.shape)

            partial = jax.lax.fori_loop(
                0, b, body, jnp.asarray(q.init(), jnp.dtype(p.dtype)))
            cur = out[...].reshape(range_shape)
            out[...] = jnp.asarray(p.combine(cur, partial),
                                   out.dtype).reshape(out_block)

        spec = pl.BlockSpec(out_block, lambda g: (0,) * len(out_block))
        return out_block, range_shape, spec, emit_fold  # full == block

    if isinstance(p, ir.GroupByFold):
        # terminal keyed fold: CAM template (one-hot MXU scatter) into a
        # revisited dense accumulator; combine must be elementwise add.
        if not isinstance(q, ir.GroupByFold):
            raise NotImplementedError("fused chain: keyed-fold tile "
                                      "expected under GroupByFold root")
        elem = tuple(p.elem_shape)
        k = p.num_keys
        ew = int(np.prod(elem)) if elem else 1
        out_shape = (k,) + elem
        # scalar elements would make a rank-1 (k,) block; pad to (k, 1)
        # (Mosaic wants >= 2-D blocks, same as _padded_out for folds)
        out_block = (k,) + (elem if elem else (1,))

        def emit_cam(g, out, env):
            @pl.when(g == 0)
            def _init():
                out[...] = jnp.asarray(p.init(), out.dtype
                                       ).reshape(out_block)

            def body(l):
                stack = (g, l)
                return q.fn(stack, *_read_tiles(q.reads, env, stack))

            keys, vals = jax.vmap(body)(jnp.arange(b, dtype=jnp.int32))
            onehot = jax.nn.one_hot(keys, k, dtype=out.dtype)
            vals2 = jnp.asarray(vals, out.dtype).reshape(b, ew)
            out[...] += jnp.dot(onehot.T, vals2,
                                preferred_element_type=out.dtype
                                ).reshape(out_block)

        spec = pl.BlockSpec(out_block, lambda g: (0,) * len(out_block))
        return out_block, out_shape, spec, emit_cam

    raise NotImplementedError(
        f"no fused-chain template for terminal {type(p).__name__}")


def _stage_tile_fn(stage: ir.Map) -> Callable:
    """Producer stage: compute the whole (b,)+elem tile for one grid
    step.  f(grid_idx, env) -> tile (lands in this stage's VMEM
    scratch)."""
    (b,) = stage.domain

    def run(grid_idx, env):
        def body(l):
            stack = tuple(grid_idx) + (l,)
            return stage.fn(stack, *_read_tiles(stage.reads, env, stack))

        vals = jax.vmap(body)(jnp.arange(b, dtype=jnp.int32))
        return vals.reshape((b,) + tuple(stage.elem_shape))

    return run


def _padded_out(range_shape: Tuple[int, ...]) -> Tuple[int, ...]:
    """Pallas wants >= 2-D blocks; pad scalar/vector accumulators."""
    if len(range_shape) >= 2:
        return tuple(range_shape)
    if len(range_shape) == 1:
        return (1,) + tuple(range_shape)
    return (1, 1)


def lower_fused_dag(terminals, grid_n: int, depth: int = 2) -> Callable:
    """ONE Pallas kernel for a fused pipeline DAG.

    ``terminals`` is a sequence of ``(output name, fused pattern)``
    pairs (``pipeline.fuse_dag`` output) sharing the 1-D strided grid
    ``grid_n``.  External tensors stream through double-buffered
    BlockSpecs (one operand per distinct tile, however many terminal
    trees read it); every producer stage runs once per grid step into
    its rotating ``depth``-deep VMEM scratch -- slot ``g % depth``, so
    ``depth - 1`` earlier stage tiles stay live behind the one being
    written, realizing the metapipeline buffer depth ``plan_memory``
    charges -- and is consumed in place by all its readers (fan-out
    pays a single stage execution and a single buffer); each terminal
    then updates its own output block -- revisited accumulator / CAM
    blocks for folds, a streamed write-once block for Map terminals.
    HBM is touched solely at the pipeline edges (paper Fig. 6).
    Returns ``call(**tensors) -> {name: array}``.
    """
    terminals = tuple(terminals)
    # the span times kernel *construction* (host side); the emitted
    # kernel body stays telemetry-free
    with telemetry.span("codegen.lower_fused_dag",
                        terminals=len(terminals), grid=int(grid_n),
                        depth=int(depth)):
        return _lower_fused_dag_body(terminals, grid_n, depth)


def _lower_fused_dag_body(terminals, grid_n: int, depth: int) -> Callable:
    from jax.experimental.pallas import tpu as pltpu

    if depth < 2:
        raise ValueError(f"metapipeline depth must be >= 2, got {depth}")
    for _, t in terminals:
        if not (t.strided and len(t.domain) == 1 and t.inner is not None):
            raise NotImplementedError(
                "fused chain: 1-D strided root expected")
        if tuple(t.domain) != (grid_n,):
            raise ValueError(
                f"terminal '{t.name}' grid {t.domain} != ({grid_n},)")

    tensor_groups, stage_loads = _collect_dag_loads(terminals)
    reps = [group[0] for _, group in tensor_groups]  # one DMA per group
    uid_lists = [[tc.uid for tc in group] for _, group in tensor_groups]
    in_specs = [
        pl.BlockSpec(tc.tile_shape,
                     _block_index_map(tc.index_map, tc.tile_shape, 1))
        for tc in reps
    ]
    scratch_shapes = [pltpu.VMEM((depth,) + tuple(tc.tile_shape),
                                 jnp.dtype(tc.dtype))
                      for tc in stage_loads]
    stage_fns = [_stage_tile_fn(tc.src) for tc in stage_loads]

    emitters = [_terminal_emitter(t) for _, t in terminals]
    out_specs = [spec for _, _, spec, _ in emitters]
    out_structs = [jax.ShapeDtypeStruct(full, jnp.dtype(t.dtype))
                   for (full, _, _, _), (_, t) in zip(emitters, terminals)]

    n_in, n_out = len(reps), len(terminals)

    def kernel(*refs):
        ins = refs[:n_in]
        outs = refs[n_in:n_in + n_out]
        scratch = refs[n_in + n_out:]
        g = pl.program_id(0)
        env: Dict[str, Any] = {}
        for uids, r in zip(uid_lists, ins):
            val = r[...]
            for uid in uids:  # every tree's alias of this tile
                env[uid] = val
        slot = g % depth
        for tc, fn, sc in zip(stage_loads, stage_fns, scratch):
            sc[pl.ds(slot, 1)] = fn((g,), env).astype(sc.dtype)[None]
            # consumers read the scratch ref, not the producing SSA
            # value: the scratch IS the stage's on-chip buffer (it is
            # what plan_memory charges and what the docs promise), so
            # it must not be a dead write-only allocation; the slot
            # rotates through the depth copies so successive grid
            # steps never overwrite a tile a deeper pipeline stage
            # could still be draining (WAR avoidance)
            env[tc.uid] = sc[pl.ds(slot, 1)][0]
        for (_, _, _, emit), out in zip(emitters, outs):
            emit(g, out, env)

    run = jax.jit(pl.pallas_call(
        kernel, grid=(grid_n,), in_specs=in_specs,
        out_specs=out_specs, out_shape=out_structs,
        scratch_shapes=scratch_shapes, interpret=INTERPRET))

    names = [name for name, _ in terminals]
    shapes = [shape for _, shape, _, _ in emitters]

    def call(**tensors):
        args = [jnp.asarray(tensors[tc.src.name]) for tc in reps]
        outs = run(*args)
        return {name: out.reshape(shape)
                for name, shape, out in zip(names, shapes, outs)}

    return call


def lower_fused_chain(p: ir.Pattern, depth: int = 2) -> Callable:
    """Single-terminal front-end over ``lower_fused_dag`` (the PR-2
    chain API): one fused pattern in, the bare output array out."""
    if not (p.strided and len(p.domain) == 1):
        raise NotImplementedError("fused chain: 1-D strided root expected")
    (grid_n,) = p.domain
    dag_call = lower_fused_dag(((p.name, p),), grid_n, depth=depth)

    def call(**tensors):
        return dag_call(**tensors)[p.name]

    return call


def lower_fused_pipeline(pipe, *, plan=None,
                         vmem_budget: Optional[int] = None,
                         cache=None, measure: Optional[str] = None,
                         policy=None, options=None) -> Callable:
    """Lower a ``pipeline.Pipeline`` (DAG) with a joint-DSE
    ``PipelinePlan``.

    Each plan group lowers as one multi-output megakernel
    (``lower_fused_dag``) at its own block size (``plan.group_blocks``)
    and metapipeline buffer depth (``plan.depths``: the stage scratch
    rotates that many VMEM copies); group boundaries -- present only
    on the split-fallback path when no
    fully fused candidate fits VMEM -- materialize their cut
    intermediates and chain through them.  The selected plan is exposed
    on the returned callable as ``.pipeline_plan``, and
    ``.group_lowerings`` records what each group actually compiled to
    (``megakernel`` / ``oracle-chain``) -- check it before quoting the
    plan's fused traffic numbers for an execution.  Multi-output
    pipelines return a name -> array dict.  ``policy`` (a
    ``resilience.Policy``) bounds any measured exploration the call
    triggers: per-candidate deadlines, quarantine, certification.
    """
    from .cost import VMEM_BYTES
    from .dse import explore_pipeline
    from . import pipeline as plmod

    budget = VMEM_BYTES if vmem_budget is None else vmem_budget
    if plan is None:
        plan = explore_pipeline(pipe, vmem_budget=budget, cache=cache,
                                measure=measure, policy=policy,
                                options=options)

    group_depths = plan.depths or (2,) * len(plan.groups)
    runners = []
    lowerings = []
    for (i0, i1), b, d in zip(plan.groups, plan.group_blocks,
                              group_depths):
        sub = plmod.sub_pipeline(pipe, i0, i1)
        outs = plmod.output_names(sub)
        try:
            fdag = plmod.fuse_dag(sub, b, vmem_budget_words=budget // 4)
            runner = lower_fused_dag(fdag.terminals, fdag.grid, depth=d)
            how = "megakernel"
        except NotImplementedError:
            runner = plmod.unfused_runner(sub)  # correctness first
            how = "oracle-chain"

            def as_dict(r, names):
                def run(**tensors):
                    out = r(**tensors)
                    return out if isinstance(out, dict) \
                        else {names[0]: out}
                return run

            runner = as_dict(runner, outs)
        runners.append((outs, runner))
        lowerings.append((outs[-1], how))

    out_names = plmod.output_names(pipe)

    def call(**tensors):
        env = {k: jnp.asarray(v) for k, v in tensors.items()}
        for _, runner in runners:
            env.update(runner(**env))
        if len(out_names) == 1:
            return env[out_names[0]]
        return {n: env[n] for n in out_names}

    call.pipeline_plan = plan
    call.group_lowerings = tuple(lowerings)
    return call


# --------------------------------------------------------------------
# dispatch
# --------------------------------------------------------------------


def lower(p: ir.Pattern) -> Callable:
    """Pick the template for a tiled pattern (paper: template selection)."""
    with telemetry.span("codegen.lower", kind=type(p).__name__,
                        pattern=p.name) as sp:
        if match_tiled_gemm(p):
            sp.set(template="gemm")
            return lower_tiled_gemm(p)
        if isinstance(p, ir.MultiFold) and p.combine is None \
                and isinstance(p.inner, ir.Map):
            sp.set(template="map")
            return lower_tiled_map(p)
        if isinstance(p, ir.GroupByFold) and p.strided:
            sp.set(template="groupby")
            return lower_tiled_groupby(p)
        if isinstance(p, ir.FlatMap) and p.strided:
            sp.set(template="flatmap")
            return lower_tiled_flatmap(p)
        raise NotImplementedError(
            f"no hardware template for {type(p).__name__} (strided="
            f"{p.strided}); supported: tiled Map/GEMM/GroupByFold/FlatMap")


def lower_for_timing(p: ir.Pattern, sizes: Dict[str, Tuple[int, ...]], *,
                     vmem_budget: Optional[int] = None,
                     seed: int = 0) -> Tuple[Callable[[], Any], str]:
    """Lower one tile-size candidate of an *untiled* pattern into a
    zero-arg callable ready for the timing harness (``core.measure``).

    Inputs are synthesized deterministically from the pattern's tensor
    metadata; the Pallas template is preferred, and candidates with no
    template fall back to the jitted ``codegen_jax`` oracle of the
    *tiled* IR -- the same executable the fig7 rows time, so measured
    rankings stay comparable across candidates.  On CPU the Pallas path
    runs in interpret mode (``INTERPRET``); the timing DB records that.
    Returns ``(fn, how)`` with ``how`` in {"pallas", "oracle"}.
    """
    from .codegen_jax import execute
    from .cost import VMEM_BYTES
    from .measure import synth_inputs
    from .strip_mine import insert_tile_copies, strip_mine, tile

    budget = VMEM_BYTES if vmem_budget is None else vmem_budget
    # chaos hook: REPRO_FAULTS=lower:<p> fails this lowering before any
    # fallback can mask it -- the caller's quarantine path must fire
    resilience.inject("lower", f"{type(p).__name__}:{p.name}")
    with telemetry.span("codegen.lower_for_timing",
                        kind=type(p).__name__, pattern=p.name) as sp:
        try:
            t = tile(p, sizes, vmem_budget_words=budget // 4)
        except resilience.EXPECTED_ERRORS:
            # same fallback as dse._tile_ir: interchange/lift may not
            # apply
            t = insert_tile_copies(strip_mine(p, sizes),
                                   vmem_budget_words=budget // 4)
        inputs = synth_inputs(ir.inputs_of(p), seed=seed)
        try:
            kern = lower(t)
            # abstract-trace probe: template-shape mismatches that only
            # surface at call time must route to the oracle, not blow up
            # (or silently skip) the candidate
            jax.eval_shape(lambda: kern(**inputs))
            sp.set(how="pallas")
            return (lambda: kern(**inputs)), "pallas"
        except resilience.EXPECTED_ERRORS as e:
            resilience.record_once(
                "lower", resilience.classify(e),
                f"{type(p).__name__}:{p.name}", "fallback",
                f"pallas template unusable ({e}); codegen_jax oracle of "
                "the tiled IR times instead")
            run = jax.jit(lambda **kw: execute(t, kw))
            sp.set(how="oracle")
            return (lambda: run(**inputs)), "oracle"


def lower_pipeline_for_timing(pipe, plan, *,
                              vmem_budget: Optional[int] = None,
                              seed: int = 0) -> Callable[[], Any]:
    """Lower one fused-pipeline plan candidate into a zero-arg callable
    over synthesized inputs, for the timing harness.  The plan is taken
    as-is (no DSE re-entry), so each shortlisted (block, depth) variant
    times exactly the megakernel it would ship as -- ``plan.depths``
    sizes the rotating stage scratch via ``lower_fused_pipeline``."""
    from . import pipeline as plmod
    from .measure import synth_inputs

    # chaos hook mirroring the single-pattern path
    resilience.inject("lower", f"Pipeline:{pipe.name}")
    with telemetry.span("codegen.lower_pipeline_for_timing",
                        pipeline=pipe.name, block=int(plan.block),
                        depth=int(plan.depth)):
        inputs = synth_inputs(plmod.external_inputs(pipe), seed=seed)
        call = lower_fused_pipeline(pipe, plan=plan,
                                    vmem_budget=vmem_budget)
    return lambda: call(**inputs)


def lower_auto(p: ir.Pattern, *, plan=None, vmem_budget: Optional[int] = None,
               cache=None, measure: Optional[str] = None,
               policy=None, options=None) -> Callable:
    """Tile an *untiled* pattern with a DSE-chosen ``TilePlan`` and lower
    it (paper §4 automated tile-size selection feeding §5 codegen).

    ``plan=None`` runs ``core.dse.explore`` (with its persistent tuning
    cache); pass an explicit ``TilePlan`` to reuse a prior exploration,
    or ``measure="top_k"`` to let hybrid DSE back the plan with real
    timings.  The selected plan is exposed on the returned callable as
    ``.tile_plan``, including the searched metapipeline buffer depth
    (``plan.depths``).  Single-pattern templates delegate buffering to
    the Pallas/Mosaic grid pipeliner, so the depth shapes the *pricing*
    (VMEM charge + exposed-latency model) rather than the emitted
    kernel; fused pipelines (``lower_fused_pipeline``) realize it as
    rotating stage scratch.  ``policy`` (a ``resilience.Policy``)
    bounds any measured exploration: deadlines, quarantine,
    certification.
    """
    from .cost import VMEM_BYTES
    from .dse import explore
    from .strip_mine import tile

    budget = VMEM_BYTES if vmem_budget is None else vmem_budget
    with telemetry.span("codegen.lower_auto", kind=type(p).__name__,
                        pattern=p.name):
        if plan is None:
            plan = explore(p, vmem_budget=budget, cache=cache,
                           measure=measure, policy=policy,
                           options=options)
        call = lower(tile(p, plan.sizes, vmem_budget_words=budget // 4))
    call.tile_plan = plan
    return call


# --------------------------------------------------------------------
# paged decode (serving): KV-append producer + flash-attention fold
# --------------------------------------------------------------------


def lower_paged_decode(*, batch: int, kv_heads: int, group: int,
                       head_dim: int, page_size: int, n_pages_max: int,
                       layout: str = "split",
                       pages_per_step: int = 1) -> Callable:
    """Emit the fused decode megakernel over a paged KV cache.

    The ``decode_attention`` DAG lowered as one kernel per layer: the
    KV-append producer writes the step's token into its page slot, then
    the flash-attention fold streams the request's pages with online
    softmax.  The streaming domain is *ragged* (``ir.RaggedExtent``):
    the grid iterates the static page bound ``n_pages_max`` and
    predicates in-kernel on the live ``seq_lens`` -- pages past the
    length contribute exact zeros (mask to ``-1e30`` before the
    running-max update), so the result is independent of whatever the
    unallocated page-table tail points at.

    Layouts: ``split`` takes/returns two pools ``(P, ps, Hkv, dh)``;
    ``fused`` one head-interleaved pool ``(P, ps, 2*Hkv, dh)`` (K at
    head ``2h``, V at ``2h+1``) whose page streams both operands of a
    head in one burst.  Grid is ``(batch, kv_heads)``; the pool blocks
    are whole-array and revisited (constant index map), the first grid
    step seeds the output pool from the input, and every step appends
    only its own ``(request, head)`` slice -- the TPU grid is
    sequential, so appends never race the copy.

    Returns ``call(q, new_k, new_v, pools, page_table, seq_lens) ->
    (out, new_pools)`` with ``q`` ``(B, Hkv, group, dh)``, ``new_k`` /
    ``new_v`` ``(B, Hkv, dh)`` (already rotated), ``out`` the f32
    ``(B, Hkv, group, dh)`` attention output.
    """
    if layout not in ("split", "fused"):
        raise ValueError(f"layout {layout!r}")
    # span times host-side kernel construction; nothing lands in the
    # traced/jitted kernel body
    with telemetry.span("codegen.lower_paged_decode", layout=layout,
                        batch=int(batch), page_size=int(page_size),
                        n_pages_max=int(n_pages_max)):
        return _lower_paged_decode_body(
            batch=batch, kv_heads=kv_heads, group=group,
            head_dim=head_dim, page_size=page_size,
            n_pages_max=n_pages_max, layout=layout,
            pages_per_step=pages_per_step)


def _lower_paged_decode_body(*, batch: int, kv_heads: int, group: int,
                             head_dim: int, page_size: int,
                             n_pages_max: int, layout: str,
                             pages_per_step: int) -> Callable:
    fused = layout == "fused"
    ps, npm = page_size, n_pages_max
    if npm % pages_per_step != 0:
        raise ValueError(
            f"pages_per_step {pages_per_step} must divide the static "
            f"page bound {n_pages_max}")
    NEG = -1e30
    scale = head_dim ** -0.5

    def kernel(q_ref, k_ref, v_ref, pt_ref, len_ref, *pool_refs):
        n_pools = 1 if fused else 2
        pools_in = pool_refs[:n_pools]
        out_ref = pool_refs[n_pools]
        pools_out = pool_refs[n_pools + 1:]
        b = pl.program_id(0)
        h = pl.program_id(1)

        @pl.when((b == 0) & (h == 0))
        def _seed():
            for src, dst in zip(pools_in, pools_out):
                dst[...] = src[...]

        ln = len_ref[0]
        page = pt_ref[0, pl.ds(ln // ps, 1)][0]
        slot = ln % ps
        kv_dt = pools_out[0].dtype
        newk = k_ref[0, 0].astype(kv_dt)[None, None, None, :]
        newv = v_ref[0, 0].astype(kv_dt)[None, None, None, :]
        if fused:
            pool = pools_out[0]
            pool[pl.ds(page, 1), pl.ds(slot, 1), pl.ds(2 * h, 1), :] = newk
            pool[pl.ds(page, 1), pl.ds(slot, 1),
                 pl.ds(2 * h + 1, 1), :] = newv
        else:
            kp_, vp_ = pools_out
            kp_[pl.ds(page, 1), pl.ds(slot, 1), pl.ds(h, 1), :] = newk
            vp_[pl.ds(page, 1), pl.ds(slot, 1), pl.ds(h, 1), :] = newv

        n_phys = pools_out[0].shape[0]
        q = q_ref[0, 0].astype(jnp.float32)            # (group, dh)

        def read_page(pid):
            if fused:
                pool = pools_out[0]
                kpg = pool[pl.ds(pid, 1), :, pl.ds(2 * h, 1), :]
                vpg = pool[pl.ds(pid, 1), :, pl.ds(2 * h + 1, 1), :]
            else:
                kpg = pools_out[0][pl.ds(pid, 1), :, pl.ds(h, 1), :]
                vpg = pools_out[1][pl.ds(pid, 1), :, pl.ds(h, 1), :]
            return (kpg.reshape(ps, head_dim).astype(jnp.float32),
                    vpg.reshape(ps, head_dim).astype(jnp.float32))

        def body(step, carry):
            m, el, acc = carry
            for j in range(pages_per_step):
                p = step * pages_per_step + j
                pid = jnp.clip(pt_ref[0, pl.ds(p, 1)][0], 0,
                               n_phys - 1)
                kpg, vpg = read_page(pid)
                s_ = jnp.dot(q, kpg.T,
                             preferred_element_type=jnp.float32) * scale
                slotpos = p * ps + jax.lax.broadcasted_iota(
                    jnp.int32, (1, ps), 1)
                s_ = jnp.where(slotpos <= ln, s_, NEG)  # ragged predicate
                m_new = jnp.maximum(m, s_.max(-1))
                pexp = jnp.exp(s_ - m_new[:, None])
                alpha = jnp.exp(m - m_new)
                el = el * alpha + pexp.sum(-1)
                acc = acc * alpha[:, None] + jnp.dot(
                    pexp, vpg, preferred_element_type=jnp.float32)
                m = m_new
            return m, el, acc

        m0 = jnp.full((group,), NEG, jnp.float32)
        l0 = jnp.zeros((group,), jnp.float32)
        a0 = jnp.zeros((group, head_dim), jnp.float32)
        m, el, acc = jax.lax.fori_loop(0, npm // pages_per_step, body,
                                       (m0, l0, a0))
        # the step's own token is always live, so el > 0
        out_ref[0, 0] = acc / el[:, None]

    def pool_specs(pools):
        return [pl.BlockSpec(tuple(p.shape),
                             lambda b, h, _nd=p.ndim: (0,) * _nd)
                for p in pools]

    def call(q, new_k, new_v, pools, page_table, seq_lens):
        pools = tuple(jnp.asarray(p) for p in pools)
        in_specs = [
            pl.BlockSpec((1, 1, group, head_dim),
                         lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, head_dim), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1, head_dim), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, npm), lambda b, h: (b, 0)),
            pl.BlockSpec((1,), lambda b, h: (b,)),
        ] + pool_specs(pools)
        out_specs = [
            pl.BlockSpec((1, 1, group, head_dim),
                         lambda b, h: (b, h, 0, 0)),
        ] + pool_specs(pools)
        out_shape = [jax.ShapeDtypeStruct(
            (batch, kv_heads, group, head_dim), jnp.float32)] + \
            [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in pools]
        outs = pl.pallas_call(
            kernel, grid=(batch, kv_heads), in_specs=in_specs,
            out_specs=out_specs, out_shape=out_shape,
            interpret=INTERPRET)(
                q, new_k, new_v, jnp.asarray(page_table, jnp.int32),
                jnp.asarray(seq_lens, jnp.int32), *pools)
        return outs[0], tuple(outs[1:])

    return call
