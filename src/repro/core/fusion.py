"""Tile-level fusion: lift per-element pattern sources to per-tile stages.

The paper assumes aggressive vertical fusion has run *before* tiling
(Fig. 4 is the fused k-means).  After strip mining, a fused body that
computes a per-element intermediate (e.g. the closest-centroid pair for
one point) sits inside the tile loop as a per-element pattern source.
Splitting it out per the paper's heuristic creates a per-*tile* stage --
the `minDistWithInds` stage of Fig. 5b -- which (a) enables pattern
interchange and (b) becomes a metapipeline stage with its own double
buffer.

``lift_tile_stages`` performs that split: for an unstrided pattern Q
(the tile loop) directly inside a strided outer O, any access whose
source is a per-element pattern S is rewritten to read row ``l`` of a
new stage ``S_tile = Map(Q.domain){ S }`` attached to O as a
pattern-valued TileCopy.  The split is applied only when the
intermediate (``Q.domain + S.shape``) fits on-chip (``should_split``).

``fuse_dag_stages`` extends the same lifting *across pattern
boundaries*: a DAG of whole patterns sharing one streaming domain
(producer Maps feeding terminal folds / keyed folds / write-once Maps
through named intermediate tensors) fuses into one tiled pattern per
terminal, all sharing a single strided outer shape.  Each producer
becomes a per-tile stage (pattern-valued TileCopy) created *exactly
once* -- a fan-out intermediate consumed by several stages or terminals
is represented by one TileCopy whose stable ``uid`` every consumer
references, so downstream passes (memory planning, codegen) see one
VMEM scratch buffer and one set of HBM feeds however many readers it
has.  Every read of an intermediate tensor is rewritten to read the
staged tile in place -- so intermediates never touch main memory (the
paper's vertical fusion, Fig. 4/5b).  ``fuse_pipeline_stages`` is the
chain-shaped front-end (terminal = last stage) kept from PR 2.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import numpy as np

from . import ir
from .affine import AffineMap
from .interchange import should_split


def _lift_in(outer: ir.Pattern, enc: int, budget: int) -> ir.Pattern:
    """outer = strided pattern; examine its direct inner (the tile loop)."""
    q = outer.inner
    if q is None or q.strided:
        return outer
    kq = len(q.domain)
    new_reads = []
    new_stages = []
    memo: Dict[int, ir.TileCopy] = {}
    changed = False
    for a in q.accesses:
        s = a.src
        if not isinstance(s, ir.Pattern):
            new_reads.append(a)
            continue
        inter_shape = tuple(q.domain) + tuple(s.shape)
        if not should_split(int(np.prod(inter_shape)), budget):
            new_reads.append(a)  # paper heuristic: keep fused
            continue
        if id(s) in memo:
            tc = memo[id(s)]
        else:
            # S's callables were written against (enc_outer, q_local, own);
            # inside Map(Q.domain) at outer level the stack is identical.
            stage = ir.Map(domain=tuple(q.domain), elem_shape=tuple(s.shape),
                           inner=s, name=s.name + "_stage", dtype=s.dtype)
            n_out = len(stage.shape)
            tc = ir.TileCopy(
                src=stage,
                index_map=AffineMap((0,) * n_out,
                                    tuple((0,) * enc for _ in range(n_out)),
                                    arity=enc),
                tile_shape=stage.shape, name=s.name + "_stage")
            memo[id(s)] = tc
            new_stages.append(tc)
        # Q's access now reads its local row of the staged tile
        n_out = len(tc.tile_shape)
        stack_len = enc + kq
        mat = []
        for d_out in range(n_out):
            row = [0] * stack_len
            if d_out < kq:  # leading dims index the tile row by q-local idx
                row[enc + d_out] = 1
            mat.append(tuple(row))
        window = (1,) * kq + tuple(s.shape)
        new_reads.append(dataclasses.replace(
            a, src=tc,
            index_map=AffineMap((0,) * n_out, tuple(mat), arity=stack_len),
            window=window))
        changed = True
    if not changed:
        return outer
    q2 = dataclasses.replace(q, reads=tuple(new_reads))
    return dataclasses.replace(
        outer, inner=q2, tile_loads=tuple(outer.loads) + tuple(new_stages))


def lift_tile_stages(p: ir.Pattern, *, enc: int = 0,
                     vmem_budget_words: int = 4 * 1024 * 1024) -> ir.Pattern:
    """Apply the stage-lifting split everywhere it matches (post-order)."""

    def visit(node: ir.Pattern, enc_: int) -> ir.Pattern:
        updates = {}
        if node.inner is not None:
            updates["inner"] = visit(node.inner, enc_ + len(node.domain))
        rr, ch = [], False
        for a in node.accesses:
            if isinstance(a.src, ir.Pattern):
                ns = visit(a.src, enc_ + len(node.domain))
                if ns is not a.src:
                    rr.append(dataclasses.replace(a, src=ns))
                    ch = True
                    continue
            rr.append(a)
        if ch:
            updates["reads"] = tuple(rr)
        if updates:
            node = dataclasses.replace(node, **updates)
        if node.strided:
            node = _lift_in(node, enc_ + len(node.domain), vmem_budget_words)
        return node

    return visit(p, enc)


# --------------------------------------------------------------------------
# Cross-pattern lifting: fuse a pipeline of whole patterns into one
# tiled pattern (the stage-lifting split applied across pattern
# boundaries instead of within one body).
# --------------------------------------------------------------------------


def _rewire_intermediates(tile_pat: ir.Pattern, orig: ir.Pattern,
                          stage_tcs: Dict[str, ir.TileCopy]) -> ir.Pattern:
    """Redirect ``tile_pat``'s reads of intermediate tensors to the
    staged tiles.

    ``tile_pat`` is the strip-mined tile loop of ``orig`` (reads written
    against the (grid, local) stack); any read whose *original* source
    is a Tensor named like a staged producer becomes a read of row ``l``
    of that producer's TileCopy.  Only plain row accesses along the
    shared streaming domain are fusable -- anything else (shuffles,
    gathers across the boundary) must stay an HBM round-trip.
    """
    new_reads, changed = [], False
    for a_t, a_o in zip(tile_pat.reads, orig.reads):
        src = a_o.src
        if not (isinstance(src, ir.Tensor) and src.name in stage_tcs):
            new_reads.append(a_t)
            continue
        amap = AffineMap.probe(a_o.index_map, len(orig.domain))
        row_col = (1,) + (0,) * (amap.n_out - 1)
        if amap.base != (0,) * amap.n_out or amap.col(0) != row_col:
            raise NotImplementedError(
                f"pipeline fusion: read of intermediate '{src.name}' is "
                "not a row access along the shared domain "
                f"(base={amap.base}, col={amap.col(0)})")
        tc = stage_tcs[src.name]
        # at tile level the stack is (g, l); the staged tile holds the
        # current grid step's rows, so dim 0 indexes by the local l only
        mat = tuple((0, 1) if d == 0 else (0, 0)
                    for d in range(amap.n_out))
        new_reads.append(dataclasses.replace(
            a_t, src=tc,
            index_map=AffineMap((0,) * amap.n_out, mat, arity=2),
            window=a_o.window))
        changed = True
    if not changed:
        return tile_pat
    return dataclasses.replace(tile_pat, reads=tuple(new_reads))


def _stage_deps(stage: ir.Pattern, names: set) -> Tuple[str, ...]:
    """Names of the intermediates ``stage`` reads directly."""
    return tuple(a.src.name for a in stage.accesses
                 if isinstance(a.src, ir.Tensor) and a.src.name in names)


def fuse_dag_stages(stages: Sequence[ir.Pattern],
                    terminal_names: Sequence[str],
                    block: int) -> Dict[str, ir.Pattern]:
    """Fuse a DAG of untiled patterns over one shared 1-D domain.

    ``stages`` are in topological order; stages whose names are not in
    ``terminal_names`` are producer ``Map``s whose outputs later stages
    consume as Tensors named after the producing stage.  Returns one
    strip-mined pattern per terminal, each carrying the producer stages
    it (transitively) needs as per-tile pattern-valued TileCopies with
    intermediate reads rewired in place.  A producer consumed by
    several stages (fan-out) is lifted exactly once: all its consumers
    -- across terminals too -- reference the *same* TileCopy (same
    ``uid``), which is what keeps its VMEM scratch and HBM feeds from
    being duplicated downstream.  Run ``strip_mine.insert_tile_copies``
    on each terminal afterwards to materialize the external tensor
    tiles.
    """
    from . import telemetry

    with telemetry.span("fusion.fuse_dag", stages=len(stages),
                        terminals=len(terminal_names),
                        block=int(block)):
        return _fuse_dag_body(stages, terminal_names, block)


def _fuse_dag_body(stages: Sequence[ir.Pattern],
                   terminal_names: Sequence[str],
                   block: int) -> Dict[str, ir.Pattern]:
    from .strip_mine import strip_mine  # local import: avoid cycle

    names = {s.name for s in stages}
    term_set = set(terminal_names)
    producers = [s for s in stages if s.name not in term_set]
    terminals = [s for s in stages if s.name in term_set]
    if any(len(s.domain) != 1 for s in stages):
        raise NotImplementedError("pipeline fusion: 1-D shared domain only")
    (n,) = terminals[-1].domain
    if any(s.domain != (n,) for s in stages):
        raise ValueError(
            f"pipeline stages must share the streaming domain ({n},): "
            f"{[s.domain for s in stages]}")
    if n % block != 0:
        raise ValueError(f"tile {block} must divide shared extent {n}")
    for s in producers:
        if not isinstance(s, ir.Map):
            raise NotImplementedError(
                f"pipeline producers must be Maps, got {type(s).__name__}")

    stage_tcs: Dict[str, ir.TileCopy] = {}
    deps: Dict[str, Tuple[str, ...]] = {}
    for s in producers:
        deps[s.name] = _stage_deps(s, names)
        stage_inner = strip_mine(s, {s.name: (block,)}).inner
        stage_inner = _rewire_intermediates(stage_inner, s, stage_tcs)
        n_out = 1 + len(s.elem_shape)
        tc = ir.TileCopy(
            src=stage_inner,
            index_map=AffineMap((0,) * n_out,
                                tuple((0,) for _ in range(n_out)),
                                arity=1),
            tile_shape=(block,) + tuple(s.elem_shape),
            name=s.name + "_stage")
        stage_tcs[s.name] = tc

    def closure(seed: Tuple[str, ...]) -> Tuple[str, ...]:
        """Transitive producer deps of ``seed``, in stage-lift order."""
        need = set()
        frontier = list(seed)
        while frontier:
            nm = frontier.pop()
            if nm in need or nm not in stage_tcs:
                continue
            need.add(nm)
            frontier.extend(deps.get(nm, ()))
        return tuple(nm for nm in stage_tcs if nm in need)

    out: Dict[str, ir.Pattern] = {}
    for t in terminals:
        outer = strip_mine(t, {t.name: (block,)})
        q2 = _rewire_intermediates(outer.inner, t, stage_tcs)
        needed = closure(_stage_deps(t, names))
        out[t.name] = dataclasses.replace(
            outer, inner=q2,
            tile_loads=tuple(outer.loads)
            + tuple(stage_tcs[nm] for nm in needed))
    return out


def fuse_pipeline_stages(stages: Sequence[ir.Pattern],
                         block: int) -> ir.Pattern:
    """Fuse a *chain*: ``stages[:-1]`` produce, ``stages[-1]`` is the
    single terminal.  The chain-shaped front-end over
    ``fuse_dag_stages`` (PR-2 API, kept for kernels and tests)."""
    terminal = stages[-1]
    return fuse_dag_stages(stages, (terminal.name,), block)[terminal.name]


# --------------------------------------------------------------------------
# TileCopy identity across fused terminal trees
# --------------------------------------------------------------------------


def tile_copy_key(tc: ir.TileCopy):
    """Deduplication key for tile copies of *external tensors*.

    ``insert_tile_copies`` CSEs within one tree, but a DAG pipeline
    fuses one tree per terminal, so two terminals reading the same
    tensor tile carry distinct TileCopy objects (distinct uids) for the
    same DMA.  Copies with equal keys move the same data on the same
    schedule and collapse to a single BlockSpec operand / VMEM buffer;
    pattern-valued stages keep uid identity (they are already shared).
    """
    if isinstance(tc.src, ir.Tensor) and isinstance(tc.index_map, AffineMap):
        return ("tensor", tc.src.name, tc.index_map.base, tc.index_map.mat,
                tuple(tc.tile_shape), tc.hoisted)
    return ("uid", tc.uid)
