"""Checkpointing: atomic, async, reshardable.

Design for 1000+ nodes (documented here, exercised at container scale):

  * every host writes only its local shards (here: the single process
    writes all); the manifest records the global tree structure and
    step, so restore works on a *different* mesh (elastic rescale) by
    ``jax.device_put``-ing each tensor to its new NamedSharding;
  * writes go to ``<dir>/tmp-<step>`` then ``os.replace`` to
    ``step-<step>`` -- a torn write can never shadow a good checkpoint;
  * an async writer thread overlaps serialization with training; the
    train loop only blocks if a previous save is still in flight
    (bounded queue of 1 -- backpressure instead of unbounded memory);
  * ``restore_latest`` scans for the newest complete checkpoint and
    verifies the manifest hash, skipping torn ones (crash tolerance).
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np

# dtypes numpy cannot serialize natively: stored as bit-pattern views
_VIEW_AS = {"bfloat16": (np.uint16, ml_dtypes.bfloat16),
            "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn)}


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(directory: str, step: int, tree: Any) -> str:
    """Atomic synchronous save; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp-{step}")
    final = os.path.join(directory, f"step-{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(tree)
    dtypes = {k: str(v.dtype) for k, v in arrays.items()}
    stored = {k: (v.view(_VIEW_AS[str(v.dtype)][0])
                  if str(v.dtype) in _VIEW_AS else v)
              for k, v in arrays.items()}
    np.savez(os.path.join(tmp, "shards.npz"), **stored)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": dtypes,
    }
    blob = json.dumps(manifest, sort_keys=True).encode()
    manifest["hash"] = hashlib.sha256(blob).hexdigest()
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def _verify(path: str) -> Optional[Dict]:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        h = manifest.pop("hash")
        blob = json.dumps(manifest, sort_keys=True).encode()
        if hashlib.sha256(blob).hexdigest() != h:
            return None
        return manifest
    except Exception:
        return None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step-"):
            if _verify(os.path.join(directory, name)) is not None:
                steps.append(int(name.split("-", 1)[1]))
    return max(steps) if steps else None


def restore(directory: str, step: int, like: Any,
            shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like``; optionally place each
    tensor with ``shardings`` (same tree structure) -- this is the
    elastic-remesh path: the checkpoint written on a 16x16 mesh loads
    onto whatever mesh the survivors form."""
    path = os.path.join(directory, f"step-{step}")
    manifest = _verify(path)
    if manifest is None:
        raise IOError(f"checkpoint {path} is torn or missing")
    with np.load(os.path.join(path, "shards.npz")) as z:
        arrays = {}
        for k in z.files:
            a = z[k]
            logical = manifest["dtypes"][k]
            if logical in _VIEW_AS:
                a = a.view(_VIEW_AS[logical][1])
            arrays[k] = a

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    sh_flat = (jax.tree_util.tree_leaves(shardings)
               if shardings is not None else [None] * len(flat))
    leaves = []
    for (pathk, leaf), sh in zip(flat, sh_flat):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pathk)
        arr = arrays[key]
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Bounded-queue background writer (overlap save with compute)."""

    def __init__(self, directory: str):
        self.directory = directory
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                save(self.directory, step, tree)
            except BaseException as e:  # surfaced on next save/close
                self._err = e

    def save_async(self, step: int, tree: Any) -> None:
        if self._err:
            raise self._err
        # block until device->host copy done so donation is safe
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((step, host_tree))  # blocks if previous in flight

    def close(self) -> None:
        self._q.put(None)
        self._thread.join()
        if self._err:
            raise self._err
