"""Parallel Pattern Language (PPL) intermediate representation.

This is the IR from "Generating Configurable Hardware from Parallel
Patterns" (Prabhakar et al., 2015), Figure 2, adapted for a TPU target:

    Map(d)(m)                 : V_D   -- one value per index, fixed range
    MultiFold(d)(r)(z)(f)(c)  : V_R   -- fold generated values into a region
                                         of a larger accumulator
    FlatMap(d)(n)             : V_1   -- dynamic-size concat (1-D domain)
    GroupByFold(d)(z)(g)(c)   : (K,V) -- keyed fold (1-D domain)

Design notes (see DESIGN.md section 2/3):

* Pattern *bodies* are tile-level JAX callables; *access patterns* are
  explicit ``Access`` descriptors (an index map + window, exactly the
  information a Pallas ``BlockSpec`` needs).  The frontend in
  ``repro.patterns`` builds these descriptors the way the Delite DSL
  frontend of the paper would have.
* Transformations (strip mining, interchange) are structural rewrites on
  the pattern tree; nesting is explicit: an outer pattern whose body is
  another pattern carries it in ``inner`` with a list of ``TileCopy``
  load stages, mirroring the paper's tiled IR.
* TPU adaptations of dynamic structures: FlatMap bodies declare a static
  ``max_per_iter`` (mask + prefix-sum compaction replaces the FPGA
  parallel FIFO) and GroupByFold declares ``num_keys`` (dense one-hot
  accumulation replaces the FPGA CAM).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple, Union

import numpy as np

# --------------------------------------------------------------------------
# Symbolic tensors and accesses
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Tensor:
    """A symbolic dense array living in main (HBM / off-chip) memory."""

    name: str
    shape: Tuple[int, ...]
    dtype: str = "float32"

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    def __repr__(self) -> str:  # compact for transformation-rule tests
        return f"{self.name}:{'x'.join(map(str, self.shape))}"


_UID = itertools.count()


def _next_uid() -> str:
    return f"tc{next(_UID)}"


@dataclass(frozen=True)
class TileCopy:
    """An explicit on-chip copy of a tile of ``src`` (paper: ``x.copy(b+ii,*)``).

    ``index_map`` maps the *outer* (strided) domain index to the element
    offset of the tile; ``tile_shape`` is the copied region.  This is
    precisely a Pallas ``BlockSpec(block_shape=tile_shape, index_map=...)``
    and is what the memory-allocation pass turns into a (double-)buffer.

    ``reuse`` marks overlapping tiles (e.g. sliding windows) whose
    generation rules avoid redundant main-memory reads.
    """

    src: Union[Tensor, "Pattern"]
    index_map: Callable[..., Tuple[int, ...]]
    tile_shape: Tuple[int, ...]
    name: str = "tile"
    reuse: int = 1
    hoisted: bool = False  # loop-invariant: loaded once (Fig. 6 "Pipe 0")
    # stable identity across tree rewrites (dataclasses.replace keeps it):
    # an access's src copy and the (possibly rebuilt) load in tile_loads
    # refer to the same on-chip buffer iff uids match.
    uid: str = field(default_factory=_next_uid)

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.tile_shape

    @property
    def dtype(self) -> str:
        return self.src.dtype

    @property
    def words(self) -> int:
        return int(np.prod(self.tile_shape))

    def __repr__(self) -> str:
        src = self.src.name if isinstance(self.src, Tensor) else "<pattern>"
        return f"copy({src}, {'x'.join(map(str, self.tile_shape))})"


Source = Union[Tensor, TileCopy, "Pattern"]


@dataclass(frozen=True)
class Access:
    """A read of ``src`` performed at every index of a pattern's domain.

    ``index_map(idx) -> start offsets`` and ``window`` describe the region
    read per iteration.  ``affine=False`` marks data-dependent (gather)
    accesses -- these are the cases polyhedral tiling rejects and the
    paper handles by inferring caches / CAMs; we keep them out of tile
    copies and lower them to gathers (TPU: dynamic_slice / one-hot).
    """

    src: Source
    index_map: Callable[..., Tuple[int, ...]]
    window: Tuple[int, ...]
    affine: bool = True
    name: str = ""

    @property
    def words(self) -> int:
        return int(np.prod(self.window))


def whole(src: Source) -> Access:
    """Access reading the entire source every iteration."""
    shape = src.shape
    return Access(src, lambda *i: (0,) * len(shape), shape, affine=True)


def row(src: Source, dim: int = 0) -> Access:
    """Access reading row ``idx`` along ``dim`` (1-D domain)."""
    shape = src.shape

    def imap(i):
        start = [0] * len(shape)
        start[dim] = i
        return tuple(start)

    window = tuple(1 if d == dim else s for d, s in enumerate(shape))
    return Access(src, imap, window, affine=True)


def elem(src: Source) -> Access:
    """Access reading the single element at the domain index."""
    shape = src.shape
    return Access(src, lambda *i: tuple(i), (1,) * len(shape), affine=True)


# --------------------------------------------------------------------------
# Patterns
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class RaggedExtent:
    """A bounded-dynamic streaming extent (serving decode: per-request
    ``seq_len``).

    The pattern's static ``domain`` stays at the upper bound ``max`` --
    tiling, memory planning and the grid all see a static extent -- but
    at run time only the leading ``length_name`` elements are live.
    Codegen keeps the static grid and predicates in-kernel (elements
    past the length are masked); the cost model prices traffic at the
    ``granularity``-rounded live extent instead of the bound (a paged
    KV cache streams whole pages, so ``granularity`` = page size).
    """

    max: int
    length_name: str       # runtime scalar input holding the live extent
    granularity: int = 1   # mask granularity (page size); divides traffic

    @property
    def max_units(self) -> int:
        """Upper bound in granularity units (static page-count grid)."""
        return -(-self.max // self.granularity)


@dataclass(frozen=True)
class Pattern:
    """Base class; ``domain`` is the iteration space extent."""

    domain: Tuple[int, ...]

    @property
    def trip_count(self) -> int:
        return int(np.prod(self.domain))

    # sources read by the body at every domain index
    @property
    def accesses(self) -> Tuple[Access, ...]:
        return getattr(self, "reads", ())

    @property
    def loads(self) -> Tuple[TileCopy, ...]:
        """Tile copies hoisted into this pattern's body (post strip-mining)."""
        return getattr(self, "tile_loads", ())


@dataclass(frozen=True)
class Map(Pattern):
    """``Map(d)(m) : V_D`` -- one value of shape ``elem_shape`` per index.

    Output shape is ``domain + elem_shape`` (elem_shape=() for scalars).
    ``fn(idx, *windows) -> value`` where ``windows`` are the regions named
    by ``reads`` (jnp arrays of ``Access.window`` shape, squeezed).
    """

    elem_shape: Tuple[int, ...] = ()
    reads: Tuple[Access, ...] = ()
    fn: Optional[Callable] = None
    tile_loads: Tuple[TileCopy, ...] = ()
    inner: Optional["Pattern"] = None  # nested per-element pattern / tiled body
    strided: bool = False  # True for grid (strip-mined outer) domains
    name: str = "map"
    dtype: str = "float32"
    ragged: Optional[RaggedExtent] = None  # bounded-dynamic 1-D domain

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self.domain) + tuple(self.elem_shape)


@dataclass(frozen=True)
class MultiFold(Pattern):
    """``MultiFold(d)(r)(z)(f)(c) : V_R``.

    Per index the body produces ``(out_index, update)`` where ``update``
    consumes the current accumulator slice of shape ``update_shape`` at
    ``out_index`` and returns its new value.  ``combine`` merges parallel
    partial accumulators (must be associative; ``init`` its identity).

    ``fn(idx, acc_slice, *windows) -> new_slice``;
    ``out_index_map(idx) -> start offsets`` into the ``range_shape`` acc.
    A classic ``fold`` is the special case ``update_shape == range_shape``
    and ``out_index_map == lambda *i: zeros`` (every iteration updates the
    whole accumulator) -- test with ``is_fold``.
    ``combine=None`` marks the write-once case (strided tiled Map), shown
    as ``(_)`` in the paper's Table 1.
    """

    range_shape: Tuple[int, ...] = ()
    init: Optional[Callable[[], Any]] = None
    reads: Tuple[Access, ...] = ()
    out_index_map: Optional[Callable] = None
    update_shape: Tuple[int, ...] = ()
    fn: Optional[Callable] = None
    combine: Optional[Callable] = None
    tile_loads: Tuple[TileCopy, ...] = ()
    inner: Optional["Pattern"] = None
    strided: bool = False
    name: str = "multifold"
    dtype: str = "float32"
    ragged: Optional[RaggedExtent] = None  # bounded-dynamic 1-D domain

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self.range_shape)

    @property
    def is_fold(self) -> bool:
        return tuple(self.update_shape) == tuple(self.range_shape)


@dataclass(frozen=True)
class FlatMap(Pattern):
    """``FlatMap(d)(n) : V_1`` -- 1-D domain, dynamic output size.

    TPU adaptation: ``fn(idx, *windows) -> (values, count)`` with
    ``values.shape == (max_per_iter,) + elem_shape`` and ``count`` the
    number of valid leading entries.  Output realizes as a static
    ``(domain * max_per_iter,)`` buffer plus a total count (the FPGA
    parallel FIFO becomes mask + prefix-sum compaction).
    """

    max_per_iter: int = 1
    elem_shape: Tuple[int, ...] = ()
    reads: Tuple[Access, ...] = ()
    fn: Optional[Callable] = None
    tile_loads: Tuple[TileCopy, ...] = ()
    inner: Optional["Pattern"] = None
    strided: bool = False
    name: str = "flatmap"
    dtype: str = "float32"

    @property
    def shape(self) -> Tuple[int, ...]:
        return (self.trip_count * self.max_per_iter,) + tuple(self.elem_shape)


@dataclass(frozen=True)
class GroupByFold(Pattern):
    """``GroupByFold(d)(z)(g)(c) : (K,V)_1`` -- keyed fold, 1-D domain.

    TPU adaptation: the key space is bounded by ``num_keys`` so the
    accumulator realizes as a dense ``(num_keys,) + elem_shape`` array
    (one-hot matmul scatter replaces the FPGA CAM).
    ``fn(idx, *windows) -> (key, value)``; ``combine(a, b)`` elementwise.
    """

    num_keys: int = 1
    elem_shape: Tuple[int, ...] = ()
    init: Optional[Callable[[], Any]] = None
    reads: Tuple[Access, ...] = ()
    fn: Optional[Callable] = None
    combine: Optional[Callable] = None
    tile_loads: Tuple[TileCopy, ...] = ()
    inner: Optional["Pattern"] = None
    strided: bool = False
    name: str = "groupbyfold"
    dtype: str = "float32"

    @property
    def shape(self) -> Tuple[int, ...]:
        return (self.num_keys,) + tuple(self.elem_shape)


PATTERN_TYPES = (Map, MultiFold, FlatMap, GroupByFold)


# --------------------------------------------------------------------------
# Traversal / structural helpers
# --------------------------------------------------------------------------


def children(p: Pattern) -> Tuple[Pattern, ...]:
    out = []
    if p.inner is not None:
        out.append(p.inner)
    for tc in p.loads:
        if isinstance(tc.src, Pattern):
            out.append(tc.src)
    for a in p.accesses:
        if isinstance(a.src, Pattern):
            out.append(a.src)
    return tuple(out)


def walk(p: Pattern):
    """Pre-order traversal of the pattern tree."""
    yield p
    for c in children(p):
        yield from walk(c)


def nesting_depth(p: Pattern) -> int:
    d = 1
    while p.inner is not None:
        d += 1
        p = p.inner
    return d


def inputs_of(p: Pattern) -> Tuple[Tensor, ...]:
    """All main-memory tensors read anywhere in the tree (dedup, ordered)."""
    seen: dict = {}
    for node in walk(p):
        for a in node.accesses:
            if isinstance(a.src, Tensor):
                seen.setdefault(a.src.name, a.src)
        for tc in node.loads:
            if isinstance(tc.src, Tensor):
                seen.setdefault(tc.src.name, tc.src)
    return tuple(seen.values())


def describe(p: Pattern, indent: int = 0) -> str:
    """Structural pretty-printer used by the transformation-rule tests."""
    pad = "  " * indent
    kind = type(p).__name__
    dom = "x".join(map(str, p.domain))
    extra = ""
    if isinstance(p, MultiFold):
        extra = f" range={'x'.join(map(str, p.range_shape)) or 'scalar'}"
        if p.combine is None:
            extra += " (_)"
        if p.is_fold:
            extra += " [fold]"
    if isinstance(p, GroupByFold):
        extra = f" keys={p.num_keys}"
    lines = [f"{pad}{kind}({dom}){extra}"]
    for tc in p.loads:
        lines.append(f"{pad}  {tc!r}" + (" [hoisted]" if tc.hoisted else ""))
        if isinstance(tc.src, Pattern):
            lines.append(describe(tc.src, indent + 2))
    for a in p.accesses:
        if isinstance(a.src, Pattern):
            lines.append(f"{pad}  <src pattern>")
            lines.append(describe(a.src, indent + 2))
    if p.inner is not None:
        lines.append(describe(p.inner, indent + 1))
    return "\n".join(lines)


def signature(p: Pattern) -> Tuple:
    """Hashable structural signature (used for CSE of tile copies and in
    rule tests: two IRs are structurally equal iff signatures match)."""
    sig: Tuple = (type(p).__name__, tuple(p.domain))
    if isinstance(p, MultiFold):
        sig += (tuple(p.range_shape), p.combine is None)
    if isinstance(p, GroupByFold):
        sig += (p.num_keys,)
    sig += (tuple((repr(tc)) for tc in p.loads),)
    rag = getattr(p, "ragged", None)
    if rag is not None:   # appended only when present: static-extent
        sig += (("ragged", rag.max, rag.length_name, rag.granularity),)
    if p.inner is not None:
        sig += (signature(p.inner),)
    return sig
