"""Decoder-only transformer (dense GQA / MoE / multimodal backbones).

Pure JAX: params are dict pytrees with a stacked leading layer dim,
consumed by ``jax.lax.scan`` so the lowered HLO stays small for 80-layer
72B-parameter configs compiled on 512 dry-run devices.  Supports:

  * GQA / MQA attention with RoPE, optional QKV bias (Qwen-2), optional
    sliding window (Mixtral), squared-ReLU FFN (Nemotron-4);
  * MoE FFN layers (every ``moe_layer_period``-th layer);
  * multi-codebook token embeddings / heads (MusicGen) and prefix
    embeddings from a stubbed modality frontend (InternVL);
  * full-sequence forward (training / prefill) and single-token decode
    with a preallocated KV cache (sliding-window configs keep a
    ring-buffer cache of ``min(window, max_len)``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as moe_mod
from .config import ModelConfig
from .sharding import hint

Params = Dict[str, Any]


# ----------------------------------------------------------------- shapes
def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[Tuple[int, ...], str]]:
    """name -> (shape, init_kind); init_kind in {embed, dense, zeros}."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.padded_vocab
    nl = cfg.n_layers
    qk, kv = cfg.qk_dim, cfg.kv_dim
    shapes: Dict[str, Tuple[Tuple[int, ...], str]] = {}
    if cfg.n_codebooks:
        shapes["embed"] = ((cfg.n_codebooks, v, d), "embed")
        shapes["lm_head"] = ((cfg.n_codebooks, d, v), "dense")
    else:
        shapes["embed"] = ((v, d), "embed")
        shapes["lm_head"] = ((d, v), "dense")
    shapes["final_norm"] = ((d,), "zeros")

    shapes.update({
        "ln1": ((nl, d), "zeros"),
        "ln2": ((nl, d), "zeros"),
        "wq": ((nl, d, qk), "dense"),
        "wk": ((nl, d, kv), "dense"),
        "wv": ((nl, d, kv), "dense"),
        "wo": ((nl, qk, d), "dense"),
    })
    if cfg.qkv_bias:
        shapes.update({"bq": ((nl, qk), "zeros"),
                       "bk": ((nl, kv), "zeros"),
                       "bv": ((nl, kv), "zeros")})

    n_moe = nl // cfg.moe_layer_period if cfg.n_experts else 0
    n_dense = nl - n_moe
    if n_dense:
        shapes.update({
            "w1": ((n_dense, d, f), "dense"),
            "w2": ((n_dense, f, d), "dense"),
        })
        if cfg.activation == "swiglu":
            shapes["w3"] = ((n_dense, d, f), "dense")
    if n_moe:
        for k_, s_ in moe_mod.param_shapes(cfg, n_moe).items():
            shapes[f"moe_{k_}"] = (s_, "dense")
    return shapes


def param_specs(cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    return {k: jax.ShapeDtypeStruct(s, dt)
            for k, (s, _) in param_shapes(cfg).items()}


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = jnp.dtype(cfg.dtype)
    out = {}
    shapes = param_shapes(cfg)
    keys = jax.random.split(key, len(shapes))
    for (name, (shape, kind)), k in zip(sorted(shapes.items()), keys):
        if kind == "zeros":
            out[name] = jnp.zeros(shape, dt)
        elif kind == "embed":
            out[name] = L.embed_init(k, shape, dt)
        else:
            in_axis = -2 if len(shape) >= 2 else 0
            out[name] = L.dense_init(k, shape, in_axis=in_axis, dtype=dt)
    return out


# -------------------------------------------------------------- attention
def _attn(p: Dict, x: jax.Array, cfg: ModelConfig,
          positions: jax.Array,
          kv_cache: Optional[Tuple] = None,
          cache_index: Optional[jax.Array] = None):
    """x: (B, S, D).  With kv_cache=(k,v) of (B, Hkv, C, dh), performs
    decode: writes this step's k/v at ``cache_index`` (mod C: ring
    buffer for sliding windows) and attends over the cache."""
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"])
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, hq, dh)
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    q = hint(q, "data", None, "model", None)
    k = hint(k, "data", None, "model", None)

    group = hq // hkv
    qg = q.reshape(b, s, hkv, group, dh)

    if kv_cache is None:
        out = _sdpa_chunked(q, k, v, positions, cfg)
        out = out.reshape(b, s, hq * dh)
        return jnp.einsum("bsq,qd->bsd", out, p["wo"]), None

    if kv_cache is not None:
        ck, cv = kv_cache                       # (B, Hkv, C, dh)
        c = ck.shape[2]
        widx = (cache_index % c).astype(jnp.int32)
        ck = jax.lax.dynamic_update_slice(
            ck, k.transpose(0, 2, 1, 3).astype(ck.dtype),
            (0, 0, widx, 0))
        cv = jax.lax.dynamic_update_slice(
            cv, v.transpose(0, 2, 1, 3).astype(cv.dtype),
            (0, 0, widx, 0))
        scores = jnp.einsum("bskgh,bkch->bskgc",
                            qg.astype(jnp.float32),
                            ck.astype(jnp.float32)) * dh ** -0.5
        slotpos = jnp.arange(c)
        # ring semantics relative to the LAST slot this block wrote
        # (slots widx .. widx+s-1 hold positions cache_index ..
        # cache_index+s-1; the block never wraps the ring): slot j
        # holds absolute position last - ((wlast - j) mod C).  Each
        # query row i sits at position cache_index + i and attends
        # causally; abspos < 0 marks never-written slots (their zero
        # k/v must not leak into the softmax).
        last = cache_index + s - 1
        wlast = widx + s - 1
        abspos = last - (wlast - slotpos) % c
        qpos = cache_index + jnp.arange(s)
        valid = (abspos[None, :] <= qpos[:, None]) & (abspos >= 0)[None, :]
        if cfg.sliding_window is not None:
            valid &= abspos[None, :] > qpos[:, None] - cfg.sliding_window
        scores = jnp.where(valid[None, :, None, None, :],
                           scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bskgc,bkch->bskgh", probs,
                         cv.astype(jnp.float32))
        out = out.reshape(b, s, hq * dh).astype(x.dtype)
        return jnp.einsum("bsq,qd->bsd", out, p["wo"]), (ck, cv)

    raise AssertionError("full-sequence path returns above")


ATTN_CHUNK = 1024  # q-block size for the tiled softmax (XLA-level flash)


def _sdpa_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                  positions: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Tiled softmax attention: scan over query blocks so the (s x s)
    score tensor never materializes -- the paper's strip-mine +
    interchange applied to attention (the Pallas kernel in
    kernels/flash_attention.py is the TPU-native version; this is the
    same tiling expressed in XLA for the sharded full-model step).

    GQA keys/values are expanded to full query heads so sharding stays a
    single head axis: shard heads over "model" when divisible, else
    shard the query *sequence* (14-head InternVL, 40-head Llama-4 on a
    16-way axis); the kernel path avoids the expansion on real TPUs.

    q: (B, S, Hq, dh); k, v: (B, S, Hkv, dh) -> (B, S, Hq, dh)
    """
    from .sharding import hint_first, model_axis_size

    b, s, hq, dh = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    kq = jnp.repeat(k, group, axis=2)
    vq = jnp.repeat(v, group, axis=2)
    # pad heads to a multiple of the model axis (Llama-4's 40, MusicGen's
    # 24, InternVL's 14 on a 16-way axis): a small flop tax instead of
    # replicated attention or seq-shard gathers in the chunk loop.
    hq_orig = hq
    ms = model_axis_size()
    if ms and hq % ms != 0:
        pad = (-hq) % ms
        zq = jnp.zeros((b, s, pad, dh), q.dtype)
        q = jnp.concatenate([q, zq], axis=2)
        kq = jnp.concatenate([kq, zq], axis=2)
        vq = jnp.concatenate([vq, zq], axis=2)
        hq += pad
    head = [("data", None, "model", None)]
    q = hint_first(q, head)
    kq = hint_first(kq, head)
    vq = hint_first(vq, head)

    bq = min(ATTN_CHUNK, s)
    if s % bq != 0:
        bq = s
    n_blk = s // bq
    scale = dh ** -0.5

    # k-block streams for the online-softmax scan (leading axis is the
    # UNSHARDED block index, so scan slicing stays local)
    bk = bq
    n_kb = s // bk
    kq_blk = jnp.moveaxis(kq.reshape(b, n_kb, bk, hq, dh), 1, 0)
    vq_blk = jnp.moveaxis(vq.reshape(b, n_kb, bk, hq, dh), 1, 0)
    kpos_blk = positions.reshape(n_kb, bk)

    def one_block(i):
        """Online softmax over k-blocks: the (bq x s) probs tensor never
        materializes -- the paper's accumulator-forwarding metapipeline
        (= the Pallas kernel's structure) expressed at the XLA level,
        with running (max, sum, acc) carried between strided iterations.
        """
        qb = jax.lax.dynamic_slice_in_dim(q, i * bq, bq, axis=1)
        pb = jax.lax.dynamic_slice_in_dim(positions, i * bq, bq)
        qb = hint_first(qb, head)  # stays bf16: f32 accumulate on MXU

        def kstep(carry, inp):
            m_run, l_run, acc = carry
            kb, vb, kp = inp
            s_ = jnp.einsum("bshd,bthd->bhst", qb, kb,
                            preferred_element_type=jnp.float32) * scale
            mask = kp[None, :] <= pb[:, None]
            if cfg.sliding_window is not None:
                mask &= kp[None, :] > pb[:, None] - cfg.sliding_window
            s_ = jnp.where(mask[None, None], s_, -1e30)
            m_new = jnp.maximum(m_run, s_.max(-1))
            p = jnp.exp(s_ - m_new[..., None])
            alpha = jnp.exp(m_run - m_new)
            l_new = l_run * alpha + p.sum(-1)
            acc = (acc * alpha[..., None]
                   + jnp.einsum("bhst,bthd->bhsd", p.astype(vb.dtype),
                                vb, preferred_element_type=jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hq, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hq, bq), jnp.float32)
        a0 = jnp.zeros((b, hq, bq, dh), jnp.float32)
        # remat each k-step: its backward recomputes the (bq x bk) probs
        # instead of saving them for every step (flash backward)
        (m_f, l_f, acc), _ = jax.lax.scan(
            jax.checkpoint(kstep), (m0, l0, a0),
            (kq_blk, vq_blk, kpos_blk))
        denom = jnp.where(l_f == 0.0, 1.0, l_f)
        out = (acc / denom[..., None]).astype(vq.dtype)
        out = jnp.moveaxis(out, 1, 2)              # (b, bq, h, dh)
        return hint_first(out, head)

    if n_blk == 1:
        out = one_block(0)
    else:
        # remat each q-block: backward recomputes its k-scan
        outs = jax.lax.map(jax.checkpoint(one_block),
                           jnp.arange(n_blk, dtype=jnp.int32))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, hq, dh)
    return out[:, :, :hq_orig, :]


def _dense_ffn(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = L.activation("silu" if cfg.activation == "swiglu"
                       else cfg.activation)
    h = jnp.einsum("bsd,df->bsf", x, p["w1"])
    if cfg.activation == "swiglu":
        h = act(h) * jnp.einsum("bsd,df->bsf", x, p["w3"])
    else:
        h = act(h)
    h = hint(h, "data", None, "model")
    return jnp.einsum("bsf,fd->bsd", h, p["w2"])


def _block(slc: Dict, x, cfg: ModelConfig, positions, is_moe: bool,
           kv_cache=None, cache_index=None):
    a, new_cache = _attn(slc, L.rms_norm(x, slc["ln1"]), cfg, positions,
                         kv_cache, cache_index)
    x = x + a
    h = L.rms_norm(x, slc["ln2"])
    if is_moe:
        moe_p = {k[4:]: v for k, v in slc.items() if k.startswith("moe_")}
        x = x + moe_mod.moe_ffn(moe_p, h, cfg)
    else:
        x = x + _dense_ffn(slc, h, cfg)
    # sequence parallelism: the residual stream (and thus the per-layer
    # activations the backward scan saves) lives sequence-sharded over
    # the model axis -- 16x less saved-activation HBM per device
    x = hint(x, "data", "model", None)
    return x, new_cache


_ATTN_KEYS = ("ln1", "ln2", "wq", "wk", "wv", "wo", "bq", "bk", "bv")
_DENSE_KEYS = ("w1", "w2", "w3")


def _layer_stacks(params: Params, cfg: ModelConfig):
    """Split params into per-scan stacks: attention (all layers), dense
    ffn (dense layers), moe ffn (moe layers)."""
    attn = {k: params[k] for k in _ATTN_KEYS if k in params}
    dense = {k: params[k] for k in _DENSE_KEYS if k in params}
    moe = {k: v for k, v in params.items() if k.startswith("moe_")}
    return attn, dense, moe


def _embed_tokens(params: Params, cfg: ModelConfig,
                  tokens: jax.Array) -> jax.Array:
    if cfg.n_codebooks:
        # tokens: (B, S, n_codebooks) -- EnCodec frame stack, summed
        embs = [jnp.take(params["embed"][i], tokens[..., i], axis=0)
                for i in range(cfg.n_codebooks)]
        return sum(embs)
    return jnp.take(params["embed"], tokens, axis=0)


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence forward.  tokens: (B, S[, n_codebooks]) int32.
    prefix_embeds: (B, P, D) from the stubbed modality frontend."""
    x = _embed_tokens(params, cfg, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, d = x.shape
    x = hint(x, "data", None, None)
    positions = jnp.arange(s)
    attn, dense, moe = _layer_stacks(params, cfg)
    period = cfg.moe_layer_period if cfg.n_experts else 1
    n_super = cfg.n_layers // period

    def super_block(x, slices):
        a_slc, d_slc, m_slc = slices
        # (period-1) dense layers then 1 moe layer (period=1: moe only)
        for i in range(period - 1 if moe else period):
            sl = {k: v[i] for k, v in a_slc.items()}
            sl.update({k: v[i] for k, v in d_slc.items()})
            x, _ = _block(sl, x, cfg, positions, is_moe=False)
        if moe:
            sl = {k: v[period - 1] for k, v in a_slc.items()}
            sl.update(m_slc)
            x, _ = _block(sl, x, cfg, positions, is_moe=True)
        return x, None

    if cfg.remat:
        super_block = jax.checkpoint(
            super_block, policy=jax.checkpoint_policies.nothing_saveable)

    def stack_reshape(t):
        return t.reshape((n_super, period) + t.shape[1:])

    a_stk = jax.tree.map(stack_reshape, attn)
    if dense and moe:  # interleaved (Llama-4): dense stacks have
        # n_layers - n_moe entries = n_super * (period - 1)
        d_stk = jax.tree.map(
            lambda t: t.reshape((n_super, period - 1) + t.shape[1:]),
            dense)
    else:
        d_stk = jax.tree.map(stack_reshape, dense) if dense else {}
    m_stk = jax.tree.map(lambda t: t, moe)  # already (n_moe, ...)

    x, _ = L.scan_layers(lambda c, sl: super_block(c, sl), x,
                         (a_stk, d_stk, m_stk), cfg.unroll)
    x = L.rms_norm(x, params["final_norm"])
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,ndv->bsnv", x, params["lm_head"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits


# ------------------------------------------------------------------ decode
def cache_len(cfg: ModelConfig, max_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, max_len)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Dict:
    c = cache_len(cfg, max_len)
    dt = dtype or jnp.dtype(cfg.dtype)
    shp = (cfg.n_layers, batch, cfg.n_kv_heads, c, cfg.head_dim)
    return {"k": jnp.zeros(shp, dt), "v": jnp.zeros(shp, dt)}


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    c = cache_len(cfg, max_len)
    dt = jnp.dtype(cfg.dtype)
    shp = (cfg.n_layers, batch, cfg.n_kv_heads, c, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shp, dt),
            "v": jax.ShapeDtypeStruct(shp, dt)}


def decode_step(params: Params, cfg: ModelConfig, cache: Dict,
                tokens: jax.Array, index: jax.Array):
    """One decode step.  tokens: (B, S[, n_codebooks]); index: scalar
    current position (number of tokens already in the cache).

    ``S > 1`` is block decode -- the whole-prompt prefill path: the S
    tokens are written to the cache contiguously at ``index`` and
    attend causally among themselves and over the cache.  The block
    must not wrap the ring buffer (``index % C + S <= C``); serving
    callers chunk prompts at the ring boundary.
    """
    x = _embed_tokens(params, cfg, tokens)
    b = x.shape[0]
    s = x.shape[1]
    positions = index + jnp.arange(s, dtype=jnp.int32)
    attn, dense, moe = _layer_stacks(params, cfg)
    period = cfg.moe_layer_period if cfg.n_experts else 1
    n_super = cfg.n_layers // period

    def super_block(carry, slices):
        x = carry
        a_slc, d_slc, m_slc, kc, vc = slices
        new_k, new_v = [], []
        for i in range(period):
            is_moe = bool(moe) and i == period - 1
            sl = {k: v[i] for k, v in a_slc.items()}
            if is_moe:
                sl.update(m_slc)
            else:
                sl.update({k: v[i if moe else i] for k, v in d_slc.items()})
            x, (nk, nv) = _block(sl, x, cfg, positions, is_moe,
                                 kv_cache=(kc[i], vc[i]),
                                 cache_index=index)
            new_k.append(nk)
            new_v.append(nv)
        return x, (jnp.stack(new_k), jnp.stack(new_v))

    def stack_reshape(t):
        return t.reshape((n_super, period) + t.shape[1:])

    a_stk = jax.tree.map(stack_reshape, attn)
    if dense and moe:
        d_stk = jax.tree.map(
            lambda t: t.reshape((n_super, period - 1) + t.shape[1:]),
            dense)
    else:
        d_stk = jax.tree.map(stack_reshape, dense) if dense else {}
    m_stk = moe
    kc = stack_reshape(cache["k"])
    vc = stack_reshape(cache["v"])

    x, (nk, nv) = L.scan_layers(super_block, x,
                                (a_stk, d_stk, m_stk, kc, vc), cfg.unroll)
    x = L.rms_norm(x, params["final_norm"])
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,ndv->bsnv", x, params["lm_head"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    new_cache = {"k": nk.reshape(cache["k"].shape),
                 "v": nv.reshape(cache["v"].shape)}
    return logits, new_cache
