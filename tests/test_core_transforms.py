"""Transformation-rule tests: Table 1/2/3 and Fig. 5 of the paper.

Every transformed program must (a) have the structure the paper's tables
show and (b) compute the same value as the untransformed oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ir
from repro.core.codegen_jax import execute
from repro.core.interchange import interchange
from repro.core.strip_mine import insert_tile_copies, strip_mine, tile

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------- helpers
def mk_map_2x(d=32):
    """Table 2 row 1: x.map{e => 2*e}."""
    x = ir.Tensor("x", (d,))
    return ir.Map(domain=(d,), reads=(ir.elem(x),),
                  fn=lambda s, e: 2.0 * e, name="m")


def mk_sumrows(m=12, n=16):
    """Table 2 row 2: x.map{row => row.sum} as a MultiFold (m,n)->(m)."""
    x = ir.Tensor("x", (m, n))
    return ir.MultiFold(
        domain=(m, n), range_shape=(m,), init=lambda: jnp.zeros((m,)),
        reads=(ir.elem(x),),
        out_index_map=lambda i, j: (i,), update_shape=(1,),
        fn=lambda s, acc, e: acc + e,
        combine=lambda a, b: a + b, name="sr")


def mk_filter(d=40):
    """Table 2 row 3: x.flatMap{e => if (e > 0) [e] else []}."""
    x = ir.Tensor("x", (d,))

    def fn(s, e):
        return jnp.reshape(e, (1,)), (e > 0).astype(jnp.int32)

    return ir.FlatMap(domain=(d,), max_per_iter=1, reads=(ir.elem(x),),
                      fn=fn, name="f")


def mk_hist(d=64, k=8):
    """Table 2 row 4: histogram x.groupByFold(0){e => (e/10, 1)}{_+_}."""
    x = ir.Tensor("x", (d,))

    def fn(s, e):
        key = jnp.clip(e.astype(jnp.int32), 0, k - 1)
        return key, jnp.float32(1.0)

    return ir.GroupByFold(domain=(d,), num_keys=k, init=lambda: jnp.zeros(k),
                          reads=(ir.elem(x),), fn=fn,
                          combine=lambda a, b: a + b, name="h")


def mk_gemm(m=8, n=12, p=16):
    """Table 3: matrix multiplication Map((m,n)){ fold(p) }."""
    x = ir.Tensor("x", (m, p))
    y = ir.Tensor("y", (p, n))
    kfold = ir.MultiFold(
        domain=(p,), range_shape=(), init=lambda: jnp.zeros(()),
        reads=(
            ir.Access(x, lambda i, j, k: (i, k), (1, 1)),
            ir.Access(y, lambda i, j, k: (k, j), (1, 1)),
        ),
        out_index_map=lambda i, j, k: (), update_shape=(),
        fn=lambda s, acc, xe, ye: acc + xe * ye,
        combine=lambda a, b: a + b, name="kfold")
    return ir.Map(domain=(m, n), inner=kfold, name="gemm")


def mk_kmeans(n=24, k=6, d=5):
    """Fig. 4 k-means (fused): assignment fold + grouped scatter."""
    points = ir.Tensor("points", (n, d))
    cents = ir.Tensor("centroids", (k, d))

    assign = ir.MultiFold(
        domain=(k,), range_shape=(2,),
        init=lambda: jnp.array([jnp.inf, -1.0]),
        reads=(
            ir.Access(cents, lambda i, j: (j, 0), (1, d)),
            ir.Access(points, lambda i, j: (i, 0), (1, d)),
        ),
        out_index_map=lambda i, j: (0,), update_shape=(2,),
        fn=lambda s, acc, c_row, p_row: jnp.where(
            jnp.sum((p_row - c_row) ** 2) < acc[..., 0],
            jnp.stack([jnp.sum((p_row - c_row) ** 2),
                       jnp.float32(s[-1])]),
            acc),
        combine=lambda a, b: jnp.where(a[..., :1] <= b[..., :1], a, b),
        name="assign")

    def scatter_fn(s, pair, p_row):
        key = pair[1].astype(jnp.int32)
        val = jnp.concatenate([p_row, jnp.ones((1,))])
        return key, val

    scatter = ir.GroupByFold(
        domain=(n,), num_keys=k, elem_shape=(d + 1,),
        init=lambda: jnp.zeros((k, d + 1)),
        reads=(
            ir.Access(assign, lambda i: (0,), (2,)),
            ir.Access(points, lambda i: (i, 0), (1, d)),
        ),
        fn=scatter_fn, combine=lambda a, b: a + b, name="scatter")
    return scatter, points, cents


def _rng(*shape):
    return np.random.RandomState(sum(shape)).randn(*shape).astype(np.float32)


# ----------------------------------------------------------- Table 2 rows
class TestStripMine:
    def test_map_rule_structure(self):
        p = mk_map_2x(32)
        t = strip_mine(p, {"m": (8,)})
        # Map(d) -> MultiFold(d/b) strided write-once with inner Map(b)
        assert isinstance(t, ir.MultiFold) and t.strided
        assert t.domain == (4,) and t.combine is None
        assert isinstance(t.inner, ir.Map) and t.inner.domain == (8,)

    def test_map_rule_value(self):
        p = mk_map_2x(32)
        t = insert_tile_copies(strip_mine(p, {"m": (8,)}))
        x = _rng(32)
        np.testing.assert_allclose(execute(t, {"x": x}), 2 * x, rtol=1e-6)
        # one tile copy of shape (8,) on the inner pattern's level
        copies = [tc for q in ir.walk(t) for tc in q.loads]
        assert len(copies) == 1 and copies[0].tile_shape == (8,)

    def test_multifold_rule_structure(self):
        p = mk_sumrows(12, 16)
        t = strip_mine(p, {"sr": (4, 8)})
        assert isinstance(t, ir.MultiFold) and t.strided
        assert t.domain == (3, 2)
        assert t.update_shape == (4,)  # touched region: row tile
        assert isinstance(t.inner, ir.MultiFold)
        assert t.inner.domain == (4, 8) and t.inner.range_shape == (4,)

    def test_multifold_rule_value(self):
        p = mk_sumrows(12, 16)
        t = insert_tile_copies(strip_mine(p, {"sr": (4, 8)}))
        x = _rng(12, 16)
        np.testing.assert_allclose(execute(t, {"x": x}), x.sum(1), rtol=1e-5)
        copies = [tc for q in ir.walk(t) for tc in q.loads]
        assert len(copies) == 1 and copies[0].tile_shape == (4, 8)

    def test_flatmap_rule(self):
        p = mk_filter(40)
        t = strip_mine(p, {"f": (8,)})
        assert isinstance(t, ir.FlatMap) and t.strided and t.domain == (5,)
        assert t.max_per_iter == 8
        assert isinstance(t.inner, ir.FlatMap) and t.inner.domain == (8,)
        x = _rng(40)
        buf_t, cnt_t = execute(insert_tile_copies(t), {"x": x})
        buf_o, cnt_o = execute(p, {"x": x})
        ref = x[x > 0]
        assert int(cnt_t) == int(cnt_o) == len(ref)
        np.testing.assert_allclose(np.asarray(buf_t)[:len(ref)], ref)

    def test_groupbyfold_rule(self):
        p = mk_hist(64, 8)
        t = strip_mine(p, {"h": (16,)})
        assert isinstance(t, ir.GroupByFold) and t.strided
        assert t.domain == (4,)
        assert isinstance(t.inner, ir.GroupByFold) and t.inner.domain == (16,)
        x = np.abs(_rng(64)) * 4
        np.testing.assert_allclose(
            execute(insert_tile_copies(t), {"x": x}),
            execute(p, {"x": x}), rtol=1e-6)

    def test_untiled_dim_means_full_extent(self):
        p = mk_sumrows(12, 16)
        t = strip_mine(p, {"sr": (4, None)})
        assert t.domain == (3, 1) and t.inner.domain == (4, 16)


# ------------------------------------------------------------ Table 3 gemm
class TestGemm:
    def test_strip_mined_structure(self):
        g = mk_gemm(8, 12, 16)
        t = strip_mine(g, {"gemm": (4, 6), "kfold": (8,)})
        # outer write-once grid, inner Map tile, per-elem strided fold
        assert isinstance(t, ir.MultiFold) and t.strided and t.combine is None
        assert t.domain == (2, 2)
        assert isinstance(t.inner, ir.Map) and t.inner.domain == (4, 6)
        f = t.inner.inner
        assert isinstance(f, ir.MultiFold) and f.strided and f.domain == (2,)
        assert isinstance(f.inner, ir.MultiFold) and f.inner.domain == (8,)

    def test_interchanged_structure(self):
        g = mk_gemm(8, 12, 16)
        t = interchange(strip_mine(g, {"gemm": (4, 6), "kfold": (8,)}))
        # Table 3 right: grid -> strided fold over kk -> Map tile -> fold(b2)
        assert isinstance(t, ir.MultiFold) and t.strided and t.combine is None
        f = t.inner
        assert isinstance(f, ir.MultiFold) and f.strided and f.domain == (2,)
        assert f.range_shape == (4, 6)  # accumulates the whole output tile
        m = f.inner
        assert isinstance(m, ir.Map) and m.domain == (4, 6)
        assert isinstance(m.inner, ir.MultiFold) and m.inner.domain == (8,)

    def test_tile_copies_match_paper(self):
        g = mk_gemm(8, 12, 16)
        t = tile(g, {"gemm": (4, 6), "kfold": (8,)})
        # xTile (b0,b2) and yTile (b2,b1) attached at the kk fold level
        f = t.inner
        shapes = sorted(tc.tile_shape for tc in f.loads)
        assert shapes == [(4, 8), (8, 6)]

    def test_value_all_stages(self):
        g = mk_gemm(8, 12, 16)
        x, y = _rng(8, 16), _rng(16, 12)
        ref = x @ y
        np.testing.assert_allclose(execute(g, {"x": x, "y": y}), ref,
                                   rtol=1e-4)
        sm = strip_mine(g, {"gemm": (4, 6), "kfold": (8,)})
        np.testing.assert_allclose(execute(sm, {"x": x, "y": y}), ref,
                                   rtol=1e-4)
        full = tile(g, {"gemm": (4, 6), "kfold": (8,)})
        np.testing.assert_allclose(execute(full, {"x": x, "y": y}), ref,
                                   rtol=1e-4)


# ------------------------------------------------------------- Fig 5 kmeans
class TestKmeans:
    def _ref(self, pts, cents):
        d2 = ((pts[:, None, :] - cents[None, :, :]) ** 2).sum(-1)
        idx = d2.argmin(1)
        k, d = cents.shape
        sums = np.zeros((k, d + 1), np.float32)
        for i, p in enumerate(pts):
            sums[idx[i], :d] += p
            sums[idx[i], d] += 1
        return sums

    def test_fused_oracle(self):
        scatter, *_ = mk_kmeans(24, 6, 5)
        pts, cents = _rng(24, 5), _rng(6, 5)
        np.testing.assert_allclose(
            execute(scatter, {"points": pts, "centroids": cents}),
            self._ref(pts, cents), rtol=1e-4)

    def test_tiled_structure_fig5b(self):
        scatter, *_ = mk_kmeans(24, 6, 5)
        t = tile(scatter, {"scatter": (8,), "assign": (3,)})
        # outer GroupByFold grid over n/b0
        assert isinstance(t, ir.GroupByFold) and t.strided
        assert t.domain == (3,)
        # stage lifted at the outer level: interchanged assign fold
        stages = [tc for tc in t.loads if isinstance(tc.src, ir.Pattern)]
        assert len(stages) == 1
        st = stages[0].src
        # Fig 5b: multiFold(k/b1)(b0-pairs){ map(b0){ fold(b1) } }
        assert isinstance(st, ir.MultiFold) and st.strided
        assert st.domain == (2,) and st.range_shape == (8, 2)
        assert isinstance(st.inner, ir.Map) and st.inner.domain == (8,)
        # tensor tile copies: pt1Tile (b0,d) at outer; pt2Tile (b1,d) at stage
        tensor_copies = {tc.tile_shape
                         for q in ir.walk(t) for tc in q.loads
                         if isinstance(tc.src, ir.Tensor)}
        assert (8, 5) in tensor_copies and (3, 5) in tensor_copies

    def test_points_copy_cse(self):
        """The points tile is read by both the assign stage and the
        scatter -- CSE must merge them into a single copy (paper: 'CSE
        ... to eliminate duplicate copies')."""
        scatter, *_ = mk_kmeans(24, 6, 5)
        t = tile(scatter, {"scatter": (8,), "assign": (3,)})
        pts_copies = [tc for q in ir.walk(t) for tc in q.loads
                      if isinstance(tc.src, ir.Tensor)
                      and tc.src.name == "points"]
        assert len(pts_copies) == 1

    def test_tiled_value(self):
        scatter, *_ = mk_kmeans(24, 6, 5)
        pts, cents = _rng(24, 5), _rng(6, 5)
        t = tile(scatter, {"scatter": (8,), "assign": (3,)})
        np.testing.assert_allclose(
            execute(t, {"points": pts, "centroids": cents}),
            self._ref(pts, cents), rtol=1e-4)

    def test_strip_mine_only_value(self):
        scatter, *_ = mk_kmeans(24, 6, 5)
        pts, cents = _rng(24, 5), _rng(6, 5)
        sm = insert_tile_copies(strip_mine(
            scatter, {"scatter": (8,), "assign": (3,)}))
        np.testing.assert_allclose(
            execute(sm, {"points": pts, "centroids": cents}),
            self._ref(pts, cents), rtol=1e-4)


# ----------------------------------------------------- parallel partials
def test_multifold_parallel_partials_associative():
    p = mk_sumrows(12, 16)
    x = _rng(12, 16)
    seq = execute(p, {"x": x})
    par = execute(p, {"x": x}, parallel_partials=4)
    np.testing.assert_allclose(seq, par, rtol=1e-5)
