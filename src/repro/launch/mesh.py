"""Production mesh construction.

Importing this module never touches jax device state; meshes are built
on demand.  Single pod: 16x16 = 256 chips ("data", "model").  Multi-pod:
2x16x16 = 512 chips ("pod", "data", "model") -- the "pod" axis is the
DCN dimension and composes with "data" for gradient reduction.
"""
from __future__ import annotations

from typing import Sequence

import jax

# jax >= 0.5 requires explicit axis types; older releases (the pinned
# 0.4.x) have no ``jax.sharding.AxisType`` and reject the kwarg
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _axis_type_kwargs(n_axes: int) -> dict:
    if _AXIS_TYPE is None:
        return {}
    return {"axis_types": (_AXIS_TYPE.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_elastic_mesh(devices: Sequence, model_parallel: int = 16
                      ) -> jax.sharding.Mesh:
    """Largest (data, model) mesh from the surviving device list --
    the elastic-rescale path after a node failure (runtime/elastic.py)."""
    import numpy as np
    n = len(devices)
    while model_parallel > 1 and n % model_parallel != 0:
        model_parallel //= 2
    data = n // model_parallel
    usable = data * model_parallel
    arr = np.asarray(devices[:usable]).reshape(data, model_parallel)
    return jax.sharding.Mesh(
        arr, ("data", "model"), **_axis_type_kwargs(2))
