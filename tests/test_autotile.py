"""Tile-size DSE (the paper's future work, implemented)."""
import jax
import numpy as np

from repro.kernels import ref
from repro.kernels.autotile import select_gemm_tiles, tuned_matmul


def test_selection_prefers_reuse():
    """Bigger tiles (within VMEM) => less HBM traffic; the chosen tiles
    must beat the smallest-candidate traffic."""
    from repro.core.cost import traffic
    from repro.core.strip_mine import tile
    from repro.patterns.analytics import gemm
    m = n = k = 512
    best = select_gemm_tiles(m, n, k)
    p, sizes, _, _ = gemm(m, n, k, 128, 128, 128)
    base = traffic(tile(p, sizes)).total_reads
    assert best.traffic_words <= base
    assert best.vmem_bytes <= 16 * 2 ** 20


def test_selection_respects_vmem_budget():
    c = select_gemm_tiles(2048, 2048, 2048, vmem_budget=256 * 1024)
    assert c.vmem_bytes <= 256 * 1024


def test_tuned_matmul_correct():
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 128))
    y = jax.random.normal(jax.random.PRNGKey(1), (128, 256))
    out = tuned_matmul(x, y)
    np.testing.assert_allclose(out, ref.matmul(x, y), rtol=2e-4, atol=2e-4)
