"""Cost-model calibration: fit analytic coefficients to measured runs.

The analytic model (``core.cost``) prices a candidate as overlapped HBM
stream time at the datasheet bandwidth.  Real kernels also pay a fixed
per-grid-step cost (launch, pipeline fill, interpreter dispatch on the
CPU container) and rarely reach datasheet bandwidth, so measured runs
are regressed onto a two-term model

    measured_s  ~=  s_per_byte * stream_bytes  +  overhead_s[kind] * steps

where ``stream_bytes`` is the candidate's overlap-adjusted analytic HBM
byte count, ``steps`` its kernel grid-step count, and ``kind`` the root
pattern type (per-pattern launch overhead, the paper's per-template
fixed cost).  ``1 / s_per_byte`` is the *effective* memory-tier
bandwidth the device actually sustains.

The least-squares fit (``fit``) is deterministic -- same samples, same
coefficients bit-for-bit -- and guarded: when the affine model ranks
the in-sample candidates *worse* than a pure bandwidth rescale (which
preserves the analytic ranking exactly), the profile falls back to
scale-only, so a calibrated ranking is never worse than the
uncalibrated one on the data it was fitted to.

Profiles persist per (device kind, ``dse.MODEL_VERSION``) next to the
DSE tuning cache; ``active_profile_hash`` folds the on-disk profile
into every DSE cache key so tuned plans invalidate on recalibration.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost import HBM_BYTES_PER_S
from .measure import device_kind, spearman

UNCALIBRATED = "uncalibrated"


def _model_version() -> int:
    from .dse import MODEL_VERSION  # lazy: dse imports this module
    return MODEL_VERSION


# --------------------------------------------------------------------------
# Samples and profiles
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Sample:
    """One measured candidate: analytic features + measured seconds."""

    workload: str       # groups candidates for rank comparisons
    kind: str           # root pattern type -> overhead coefficient
    stream_bytes: float  # overlap-adjusted analytic HBM bytes
    steps: int          # kernel grid steps (fixed-cost trips)
    measured_s: float
    key: str = ""       # dedup identity (the timing-DB key)

    def to_json(self) -> Dict:
        return {"workload": self.workload, "kind": self.kind,
                "stream_bytes": float(self.stream_bytes),
                "steps": int(self.steps),
                "measured_s": float(self.measured_s), "key": self.key}

    @classmethod
    def from_json(cls, d: Dict) -> "Sample":
        return cls(workload=str(d["workload"]), kind=str(d["kind"]),
                   stream_bytes=float(d["stream_bytes"]),
                   steps=int(d["steps"]),
                   measured_s=float(d["measured_s"]),
                   key=str(d.get("key", "")))

    @property
    def identity(self) -> str:
        return self.key or f"{self.workload}|{self.stream_bytes}|{self.steps}"


@dataclasses.dataclass(frozen=True)
class CalibrationProfile:
    """Fitted coefficients for one device at one cost-model revision."""

    device: str
    model_version: int
    s_per_byte: float                 # 1 / effective tier bandwidth
    overhead_s: Dict[str, float]      # per pattern kind, per grid step
    n_samples: int = 0
    mean_abs_err_s: float = 0.0       # in-sample fit residual
    mode: str = "affine"              # "affine" | "scale"

    @property
    def bandwidth_bytes_per_s(self) -> float:
        return 1.0 / max(self.s_per_byte, 1e-30)

    def seconds(self, kind: str, stream_bytes: float,
                steps: int = 1) -> float:
        """Calibrated prediction for one candidate."""
        return (stream_bytes * self.s_per_byte
                + steps * self.overhead_s.get(kind, 0.0))

    def to_json(self) -> Dict:
        return {"device": self.device,
                "model_version": int(self.model_version),
                "s_per_byte": float(self.s_per_byte),
                "overhead_s": {k: float(v)
                               for k, v in sorted(self.overhead_s.items())},
                "n_samples": int(self.n_samples),
                "mean_abs_err_s": float(self.mean_abs_err_s),
                "mode": self.mode}

    @classmethod
    def from_json(cls, d: Dict) -> "CalibrationProfile":
        return cls(device=str(d["device"]),
                   model_version=int(d["model_version"]),
                   s_per_byte=float(d["s_per_byte"]),
                   overhead_s={k: float(v)
                               for k, v in d.get("overhead_s", {}).items()},
                   n_samples=int(d.get("n_samples", 0)),
                   mean_abs_err_s=float(d.get("mean_abs_err_s", 0.0)),
                   mode=str(d.get("mode", "affine")))

    @property
    def hash(self) -> str:
        raw = json.dumps(self.to_json(), sort_keys=True)
        return hashlib.sha256(raw.encode()).hexdigest()[:16]


# --------------------------------------------------------------------------
# Fitting
# --------------------------------------------------------------------------


def _rank_quality(samples: Sequence[Sample],
                  predict) -> float:
    """Mean per-workload Spearman rho of ``predict(sample)`` vs the
    measured seconds (workloads with < 2 candidates contribute 1.0)."""
    by_wl: Dict[str, List[Sample]] = {}
    for s in samples:
        by_wl.setdefault(s.workload, []).append(s)
    rhos = [spearman([predict(s) for s in group],
                     [s.measured_s for s in group])
            for group in by_wl.values()]
    return sum(rhos) / len(rhos)


def _weights(samples: Sequence[Sample]) -> "np.ndarray":
    """Relative (1/measured) weighting: a 90 ms GEMM sample must not
    drown out a 500 us pipeline's coefficients -- every sample counts
    by its *relative* fit error, which is also what rank fidelity
    cares about."""
    return 1.0 / np.maximum(
        np.array([s.measured_s for s in samples], dtype=np.float64),
        1e-12)


def _scale_only(samples: Sequence[Sample]) -> float:
    """Weighted least-squares bandwidth rescale through the origin
    (preserves the analytic candidate ranking exactly)."""
    w = _weights(samples)
    b = np.array([s.stream_bytes for s in samples], dtype=np.float64)
    y = np.array([s.measured_s for s in samples], dtype=np.float64)
    num = float(np.sum(w * w * y * b))
    den = float(np.sum(w * w * b * b))
    scale = num / den if den > 0 else 0.0
    return scale if scale > 0 else 1.0 / HBM_BYTES_PER_S


def fit(samples: Sequence[Sample], *, device: Optional[str] = None,
        model_version: Optional[int] = None) -> CalibrationProfile:
    """Deterministic least-squares calibration fit.

    Solves ``measured ~= s_per_byte * bytes + overhead[kind] * steps``
    over all samples jointly (one bandwidth column, one overhead column
    per pattern kind), in float64 via the normal equations with a tiny
    ridge (well-posed even when a kind has a single sample), weighted
    by 1/measured so every workload counts by *relative* error.
    Negative coefficients are clamped to the physical floor (a kernel
    cannot stream faster than free or launch in negative time), and
    the rank-quality guard above picks scale-only when the affine
    model orders the fitted candidates worse.
    """
    from . import telemetry

    # canonical sample order: the fit is bit-for-bit reproducible for
    # the same sample *set*, whatever order callers accumulated it in
    samples = sorted(samples,
                     key=lambda s: (s.workload, s.kind, s.key,
                                    s.stream_bytes, s.steps,
                                    s.measured_s))
    if not samples:
        raise ValueError("calibrate.fit: no samples")
    device = device or device_kind()
    version = _model_version() if model_version is None else model_version
    with telemetry.span("calibrate.fit", n_samples=len(samples),
                        device=device) as sp:
        prof = _fit_body(samples, device, version)
        sp.set(mode=prof.mode, mean_abs_err_s=prof.mean_abs_err_s)
    telemetry.gauge("calibrate.n_samples", len(samples))
    telemetry.gauge("calibrate.mean_abs_err_s", prof.mean_abs_err_s)
    return prof


def _fit_body(samples: Sequence[Sample], device: str,
              version: int) -> CalibrationProfile:

    kinds = sorted({s.kind for s in samples})
    col = {k: 1 + i for i, k in enumerate(kinds)}
    a = np.zeros((len(samples), 1 + len(kinds)), dtype=np.float64)
    y = np.array([s.measured_s for s in samples], dtype=np.float64)
    for i, s in enumerate(samples):
        a[i, 0] = s.stream_bytes
        a[i, col[s.kind]] = s.steps
    w = _weights(samples)
    aw = a * w[:, None]
    yw = y * w
    # column equilibration + normal equations + tiny ridge:
    # deterministic, well-posed when columns are collinear (e.g. one
    # candidate per kind), and the ridge cannot distort coefficients
    # whose natural scales differ by orders of magnitude
    norms = np.sqrt((aw * aw).sum(axis=0))
    norms = np.where(norms > 0, norms, 1.0)
    an = aw / norms
    ata = an.T @ an
    x = np.linalg.solve(ata + 1e-12 * np.eye(ata.shape[0]),
                        an.T @ yw) / norms

    s_per_byte = float(x[0])
    overhead = {k: max(float(x[col[k]]), 0.0) for k in kinds}

    scale = _scale_only(samples)
    use_scale = s_per_byte <= 0
    if not use_scale:
        affine_q = _rank_quality(
            samples, lambda s: s.stream_bytes * s_per_byte
            + s.steps * overhead.get(s.kind, 0.0))
        scale_q = _rank_quality(samples, lambda s: s.stream_bytes * scale)
        use_scale = affine_q < scale_q

    if use_scale:
        s_per_byte, overhead, mode = scale, {k: 0.0 for k in kinds}, "scale"
    else:
        mode = "affine"

    err = sum(abs(s.stream_bytes * s_per_byte
                  + s.steps * overhead.get(s.kind, 0.0) - s.measured_s)
              for s in samples) / len(samples)
    return CalibrationProfile(device=device, model_version=version,
                              s_per_byte=s_per_byte, overhead_s=overhead,
                              n_samples=len(samples),
                              mean_abs_err_s=float(err), mode=mode)


def predicted_seconds(kind: str, stream_bytes: float, steps: int = 1, *,
                      profile: Optional[CalibrationProfile] = None
                      ) -> float:
    """Price ``stream_bytes`` of overlapped HBM traffic: datasheet
    bandwidth when uncalibrated, the fitted profile otherwise.  The
    single seam through which calibration feeds ``cost.traffic``-based
    pricing (``dse.price`` / ``dse.explore_pipeline``)."""
    if profile is None:
        return stream_bytes / HBM_BYTES_PER_S
    return profile.seconds(kind, stream_bytes, steps)


# --------------------------------------------------------------------------
# Persistence (profile + sample ledger in one device-keyed file)
# --------------------------------------------------------------------------


def profile_path(device: Optional[str] = None,
                 model_version: Optional[int] = None) -> str:
    """``REPRO_CALIB_PROFILE`` if set; else a per-(device, model
    version) file next to the DSE tuning cache / in the XDG cache."""
    from .measure import cache_sibling_path

    device = device or device_kind()
    version = _model_version() if model_version is None else model_version
    return cache_sibling_path(f"calibration_{device}_v{version}.json",
                              "REPRO_CALIB_PROFILE")


def _read_doc(path: str) -> Dict:
    """Crash-safe profile/ledger read: a truncated or corrupt file is
    quarantined to ``<path>.corrupt`` (warning names it) and the
    calibration starts fresh instead of silently dropping data."""
    from . import resilience
    return resilience.load_store(path, label="calibration profile")


def load_profile(device: Optional[str] = None, *,
                 path: Optional[str] = None
                 ) -> Optional[CalibrationProfile]:
    """The persisted profile for this device at the current model
    version, or None (uncalibrated).  A profile written for another
    device or an older cost-model revision is ignored, never reused."""
    device = device or device_kind()
    path = path or profile_path(device)
    doc = _read_doc(path).get("profile")
    if not doc:
        return None
    try:
        prof = CalibrationProfile.from_json(doc)
    except (KeyError, TypeError, ValueError):
        return None
    if prof.device != device or prof.model_version != _model_version():
        return None
    return prof


def load_samples(device: Optional[str] = None, *,
                 path: Optional[str] = None) -> List[Sample]:
    path = path or profile_path(device)
    out = []
    for d in _read_doc(path).get("samples", []):
        try:
            out.append(Sample.from_json(d))
        except (KeyError, TypeError, ValueError):
            continue
    return out


_hash_cache: Dict[str, Tuple[float, str]] = {}


def active_profile_hash(device: Optional[str] = None, *,
                        path: Optional[str] = None) -> str:
    """Short hash of the on-disk profile (``"uncalibrated"`` when there
    is none) -- a component of every DSE tuning-cache key, so plans
    priced under a stale calibration are never replayed."""
    device = device or device_kind()
    path = path or profile_path(device)
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        return UNCALIBRATED
    hit = _hash_cache.get(path)
    if hit is not None and hit[0] == mtime:
        return hit[1]
    prof = load_profile(device, path=path)
    h = prof.hash if prof is not None else UNCALIBRATED
    _hash_cache[path] = (mtime, h)
    return h


def observe(new_samples: Sequence[Sample], *,
            device: Optional[str] = None,
            path: Optional[str] = None) -> CalibrationProfile:
    """Merge measured samples into the device ledger, refit, persist.

    Dedup is by sample identity (the timing-DB key), so re-exploring a
    cached candidate does not double-weight it.  Returns the refreshed
    profile (also the new ``active_profile_hash`` source).
    """
    from . import resilience

    device = device or device_kind()
    path = path or profile_path(device)
    fitted: List[CalibrationProfile] = []

    def merge(data: Dict) -> None:
        # re-reads the ledger *inside* the store lock: samples another
        # process observed between our load and our write survive
        merged: Dict[str, Sample] = {}
        for d in data.get("samples", []):
            try:
                s = Sample.from_json(d)
            except (KeyError, TypeError, ValueError):
                continue
            merged[s.identity] = s
        for s in new_samples:
            merged[s.identity] = s
        samples = [merged[k] for k in sorted(merged)]
        prof = fit(samples, device=device)
        data["profile"] = prof.to_json()
        data["samples"] = [s.to_json() for s in samples]
        fitted.append(prof)

    resilience.locked_update(path, merge, label="calibration profile",
                             prefix=".calibration.", indent=1)
    _hash_cache.pop(path, None)
    return fitted[-1]
