"""Pipeline DAG semantics: validate error paths, fan-out contracts and
the write-once Map-terminal template (the ISSUE-3 acceptance surface).

Each invalid DAG must raise a *specific* ValueError at Pipeline
construction -- cycles, dangling intermediates, fan-out into mismatched
extents, Map terminals that would revisit the streamed outer -- rather
than lowering garbage.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ir
from repro.core import pipeline as plmod
from repro.core.codegen_pallas import lower_fused_dag


def _map(name, n=64, src=None, elem=()):
    src = src if src is not None else ir.Tensor("x", (n,))
    return ir.Map(domain=(n,), elem_shape=elem,
                  reads=(ir.elem(src),),
                  fn=lambda s, e: e, name=name)


# --------------------------------------------------------- error paths
def test_validate_rejects_cycle():
    a = _map("a", src=ir.Tensor("b", (64,)))
    b = _map("b", src=ir.Tensor("a", (64,)))
    with pytest.raises(ValueError, match="cycle"):
        plmod.Pipeline(name="p", stages=(a, b))


def test_validate_rejects_self_cycle():
    a = _map("a", src=ir.Tensor("a", (64,)))
    with pytest.raises(ValueError, match="cycle"):
        plmod.Pipeline(name="p", stages=(a,))


def test_validate_rejects_dangling_intermediate():
    a = _map("a")          # produced, never consumed, not an output
    b = _map("b")
    with pytest.raises(ValueError, match="dangling intermediate 'a'"):
        plmod.Pipeline(name="p", stages=(a, b), outputs=("b",))


def test_validate_rejects_unknown_output():
    a = _map("a")
    with pytest.raises(ValueError, match="names no stage"):
        plmod.Pipeline(name="p", stages=(a,), outputs=("nope",))


def test_validate_rejects_fanout_mismatched_extents():
    n = 64
    prod = _map("prod", n)                        # produces (64,)
    ok = _map("c1", n, src=ir.Tensor("prod", (n,)))
    bad = ir.Map(domain=(n,),
                 reads=(ir.Access(ir.Tensor("prod", (n, 2)),
                                  lambda i: (i, 0), (1, 2)),),
                 fn=lambda s, e: e[0], name="c2")
    with pytest.raises(ValueError, match="mismatched extents"):
        plmod.Pipeline(name="p", stages=(prod, ok, bad))


def test_validate_rejects_map_terminal_with_revisited_outer():
    n = 64
    prod = _map("prod", n)
    # terminal Map reads the WHOLE intermediate each step: the
    # write-once streamed outer would have to revisit earlier tiles
    term = ir.Map(domain=(n,),
                  reads=(ir.whole(ir.Tensor("prod", (n,))),),
                  fn=lambda s, all_: jnp.sum(all_), name="term")
    with pytest.raises(ValueError, match="revisit"):
        plmod.Pipeline(name="p", stages=(prod, term))


def test_validate_rejects_domain_mismatch_and_tiled_stages():
    from repro.core.strip_mine import strip_mine
    a = _map("a", 64)
    with pytest.raises(ValueError, match="must be untiled"):
        plmod.Pipeline(name="p",
                       stages=(strip_mine(a, {"a": (8,)}),))


def test_validate_rejects_output_also_consumed():
    a = _map("a")
    b = _map("b", src=ir.Tensor("a", (64,)))
    with pytest.raises(NotImplementedError, match="also consumed"):
        plmod.Pipeline(name="p", stages=(a, b), outputs=("a", "b"))


def test_validate_rejects_non_map_producer():
    fold = ir.MultiFold(
        domain=(64,), range_shape=(), init=lambda: jnp.zeros(()),
        reads=(ir.elem(ir.Tensor("x", (64,))),),
        out_index_map=lambda i: (), update_shape=(),
        fn=lambda s, acc, v: acc + v, combine=lambda a, b: a + b,
        name="total")
    # a consumer forces `total` to be a producer -- but folds cannot
    # stream row-by-row into a later stage
    cons = _map("c", src=ir.Tensor("total", ()))
    with pytest.raises((NotImplementedError, ValueError)):
        plmod.Pipeline(name="p", stages=(fold, cons))


# ------------------------------------------------ fan-out tensor dedup
def test_shared_tensor_tile_deduped_across_terminals():
    """gda_moments: both keyed-fold terminals read the labels tile; the
    fused accounting and memory plan must charge that DMA once."""
    from repro.patterns.analytics import gda_moments_pipeline
    pipe, _, _ = gda_moments_pipeline()
    n = pipe.shared_extent
    block = 128
    fdag = plmod.fuse_dag(pipe, block)
    reads = plmod.dag_external_reads(fdag)
    assert reads["labels"] == n          # once per step, not per terminal
    assert reads["pts"] == n * 8         # feat's read, shared
    assert "gdam_feat" not in reads      # fan-out stage: VMEM only
    mem = plmod.fused_memory_plan(pipe, block)
    labels = [b for b in mem.buffers if b.name.startswith("labels_tile")]
    assert len(labels) == 1
    feat = [b for b in mem.buffers
            if b.name.startswith("gdam_feat_stage")]
    assert len(feat) == 1 and feat[0].double_buffered


# ------------------------------------------- Map-terminal template
def test_map_terminal_streams_write_once_blocks():
    """The normalize pipeline's terminal is a Map: its output BlockSpec
    must advance with the grid (write-once streaming), unlike the
    revisited accumulator of fold/CAM terminals."""
    from repro.patterns.analytics import normalize_pipeline
    pipe, make_inputs, reference = normalize_pipeline()
    fdag = plmod.fuse_dag(pipe, 128)
    (oname, t), = fdag.terminals
    assert isinstance(t, ir.MultiFold) and t.combine is None
    assert isinstance(t.inner, ir.Map)
    kern = lower_fused_dag(fdag.terminals, fdag.grid)
    inputs = {k: jnp.asarray(v) for k, v in make_inputs().items()}
    out = kern(**inputs)[oname]
    np.testing.assert_allclose(np.asarray(out),
                               reference(make_inputs()),
                               rtol=2e-3, atol=2e-3)
    # write-once: every row's norm is 1 (no block was overwritten /
    # left at its init value)
    norms = np.linalg.norm(np.asarray(out), axis=1)
    np.testing.assert_allclose(norms, np.ones_like(norms), rtol=1e-4)


def test_map_terminal_scalar_elem_pads_to_2d():
    """A Map terminal with elem_shape=() streams rank-1 (b,) tiles;
    the template must pad blocks to (b, 1) and reshape back."""
    n = 256
    x = ir.Tensor("x", (n,))
    double = _map("dbl", n, src=x)
    scale = ir.Map(domain=(n,),
                   reads=(ir.elem(ir.Tensor("dbl", (n,))),),
                   fn=lambda s, e: e * 3.0, name="out3")
    pipe = plmod.Pipeline(name="p", stages=(double, scale))
    xs = np.random.RandomState(0).rand(n).astype(np.float32)
    fdag = plmod.fuse_dag(pipe, 64)
    kern = lower_fused_dag(fdag.terminals, fdag.grid)
    out = kern(x=jnp.asarray(xs))["out3"]
    assert out.shape == (n,)
    np.testing.assert_allclose(np.asarray(out), xs * 3.0, rtol=1e-6)


# ----------------------------------------------- multi-output lowering
def test_three_terminal_dag_single_kernel():
    """One producer feeding three terminals of all three template kinds
    (fold, keyed fold, Map) lowers as ONE kernel with three outputs."""
    n, k = 128, 4
    x = ir.Tensor("x", (n,))
    feat = _map("feat", n, src=x)
    total = ir.MultiFold(
        domain=(n,), range_shape=(), init=lambda: jnp.zeros(()),
        reads=(ir.elem(ir.Tensor("feat", (n,))),),
        out_index_map=lambda i: (), update_shape=(),
        fn=lambda s, acc, v: acc + v, combine=lambda a, b: a + b,
        name="total")
    hist = ir.GroupByFold(
        domain=(n,), num_keys=k, elem_shape=(),
        init=lambda: jnp.zeros((k,)),
        reads=(ir.elem(ir.Tensor("feat", (n,))),),
        fn=lambda s, v: (jnp.clip(jnp.floor(v * k), 0, k - 1
                                  ).astype(jnp.int32), jnp.float32(1.0)),
        combine=lambda a, b: a + b, name="hist")
    scaled = ir.Map(domain=(n,),
                    reads=(ir.elem(ir.Tensor("feat", (n,))),),
                    fn=lambda s, v: v * 2.0, name="scaled")
    pipe = plmod.Pipeline(name="tri", stages=(feat, total, hist, scaled))
    assert plmod.output_names(pipe) == ("hist", "scaled", "total")
    assert plmod.consumers(pipe)["feat"] == ("total", "hist", "scaled")

    xs = np.random.RandomState(1).rand(n).astype(np.float32) * 0.999
    fdag = plmod.fuse_dag(pipe, 32)
    assert fdag.refcounts == {"feat": 3}
    kern = lower_fused_dag(fdag.terminals, fdag.grid)
    out = kern(x=jnp.asarray(xs))
    np.testing.assert_allclose(float(out["total"]), xs.sum(), rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(out["hist"]),
        np.bincount(np.clip((xs * k).astype(int), 0, k - 1),
                    minlength=k).astype(np.float32))
    np.testing.assert_allclose(np.asarray(out["scaled"]), xs * 2.0,
                               rtol=1e-6)
