"""User-facing pattern frontends (the paper benchmark suite)."""
from .analytics import SUITE
