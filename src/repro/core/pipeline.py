"""Pipeline fusion: lower multi-pattern programs as one Pallas kernel.

The paper's programming model composes whole patterns into pipelines
(tpchq6 = filter -> fold, gda = map -> keyed fold, kmeans = assign ->
scatter); its perf claims (Fig. 5/6, the metapipeline overlap of §5)
assume those stages are *vertically fused* so intermediates stay
on-chip.  This module is the subsystem that makes our codegen match
that model: instead of one ``pallas_call`` per pattern with every
intermediate round-tripping HBM, a :class:`Pipeline` lowers as a single
megakernel in which producer tiles land in VMEM scratch (double
buffered per the metapipeline schedule) and are consumed in place --
only pipeline inputs and the final output touch main memory.

Structure of a pipeline:

  * ``stages`` are *untiled* PPL patterns sharing one 1-D streaming
    domain ``(n,)``; every stage except the last is a producer ``Map``.
  * A stage reads an earlier stage's output as an ``ir.Tensor`` whose
    ``name`` equals the producing stage's ``name`` (a *virtual* tensor:
    it exists in HBM only on the unfused path).
  * The last stage is the terminal reduction (``MultiFold`` fold or
    ``GroupByFold``) and defines the pipeline output.

``fuse`` builds the fused tiled IR by strip-mining the terminal and
attaching each producer as a per-tile stage via
``fusion.fuse_pipeline_stages`` (the paper's stage-lifting split,
applied across pattern boundaries), then materializing external tensor
tiles with ``insert_tile_copies``.  The fused IR is ordinary tiled PPL:
``cost.traffic`` prices it, ``memory.plan_memory`` checks VMEM (stage
buffers double-buffered), ``scheduling.build_schedule`` derives the
metapipeline, ``codegen_jax.execute`` is the oracle, and
``codegen_pallas.lower_fused_chain`` emits the megakernel.

Joint tile-size selection for a pipeline lives in
``dse.explore_pipeline`` (one shared tile per streaming domain, priced
on the fused kernel, cached on the whole pipeline signature, with a
split fallback at the cheapest cut when no fused candidate fits VMEM).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from . import ir
from .cost import VMEM_BYTES, traffic
from .fusion import fuse_pipeline_stages
from .memory import plan_memory
from .scheduling import Metapipeline, build_schedule
from .strip_mine import insert_tile_copies


@dataclasses.dataclass(frozen=True)
class Pipeline:
    """A chain of untiled patterns over one shared streaming domain."""

    name: str
    stages: Tuple[ir.Pattern, ...]

    def __post_init__(self):
        validate(self)

    @property
    def terminal(self) -> ir.Pattern:
        return self.stages[-1]

    @property
    def shared_extent(self) -> int:
        return self.stages[-1].domain[0]

    @property
    def dtype(self) -> str:
        return self.terminal.dtype


def intermediate_names(pipe: Pipeline) -> Tuple[str, ...]:
    """Stage names, i.e. the virtual tensors produced inside the chain."""
    return tuple(s.name for s in pipe.stages[:-1])


def intermediate_words(pipe: Pipeline) -> Dict[str, int]:
    return {s.name: int(np.prod(s.shape)) for s in pipe.stages[:-1]}


def external_inputs(pipe: Pipeline) -> Tuple[ir.Tensor, ...]:
    """Main-memory tensors read by any stage, minus the intermediates."""
    inter = set(intermediate_names(pipe))
    seen: Dict[str, ir.Tensor] = {}
    for s in pipe.stages:
        for t in ir.inputs_of(s):
            if t.name not in inter:
                seen.setdefault(t.name, t)
    return tuple(seen.values())


def output_words(pipe: Pipeline) -> int:
    return int(np.prod(pipe.terminal.shape)) if pipe.terminal.shape else 1


def validate(pipe: Pipeline) -> None:
    if not pipe.stages:
        raise ValueError("empty pipeline")
    (n,) = pipe.stages[-1].domain
    names = set()
    for s in pipe.stages:
        if tuple(s.domain) != (n,):
            raise ValueError(
                f"stage '{s.name}' domain {s.domain} != shared ({n},)")
        if s.strided or s.loads:
            raise ValueError(f"stage '{s.name}' must be untiled")
        if s.name in names:
            raise ValueError(f"duplicate stage name '{s.name}'")
        names.add(s.name)
    for s in pipe.stages[:-1]:
        if not isinstance(s, ir.Map):
            raise NotImplementedError(
                f"producer stage '{s.name}' must be a Map")
    # wiring: a stage may only read intermediates produced *before* it
    produced: set = set()
    for s in pipe.stages:
        for a in s.accesses:
            if isinstance(a.src, ir.Tensor) and a.src.name in names:
                if a.src.name not in produced:
                    raise ValueError(
                        f"stage '{s.name}' reads '{a.src.name}' before "
                        f"it is produced")
        produced.add(s.name)


# --------------------------------------------------------------------------
# Fused IR
# --------------------------------------------------------------------------


def fuse(pipe: Pipeline, block: int, *,
         vmem_budget_words: int = VMEM_BYTES // 4) -> ir.Pattern:
    """The whole chain as one tiled pattern: producers are VMEM-resident
    per-tile stages, only external tensors get (HBM -> VMEM) tile
    copies."""
    fused = fuse_pipeline_stages(pipe.stages, block)
    return insert_tile_copies(fused, vmem_budget_words=vmem_budget_words)


def schedule(pipe: Pipeline, block: int, *,
             vmem_budget_words: int = VMEM_BYTES // 4
             ) -> Optional[Metapipeline]:
    """Metapipeline schedule of the fused kernel: every producer stage
    and tile load crossing a stage boundary is double-buffered."""
    return build_schedule(fuse(pipe, block,
                               vmem_budget_words=vmem_budget_words),
                          vmem_budget_words)


# --------------------------------------------------------------------------
# Reference execution (unfused path + oracle)
# --------------------------------------------------------------------------


def run_unfused(pipe: Pipeline, inputs: Dict[str, Any],
                *, return_intermediates: bool = False):
    """Execute stage-by-stage through the ``codegen_jax`` oracle,
    materializing every intermediate (the pre-fusion lowering: one
    kernel per pattern, intermediates round-trip HBM)."""
    from .codegen_jax import execute  # local import: avoid cycle

    env = dict(inputs)
    out = None
    for s in pipe.stages:
        out = execute(s, env)
        env[s.name] = out
    if return_intermediates:
        return out, {k: env[k] for k in intermediate_names(pipe)}
    return out


def unfused_runner(pipe: Pipeline) -> Callable:
    """A jitted closure over the unfused stage chain (inputs as kwargs)."""
    import jax

    @jax.jit
    def run(**inputs):
        return run_unfused(pipe, inputs)

    return run


# --------------------------------------------------------------------------
# Traffic accounting (the quantity joint DSE minimizes)
# --------------------------------------------------------------------------


def unfused_traffic_words(pipe: Pipeline) -> int:
    """Total HBM words moved by the per-pattern lowering: every stage's
    main-memory reads (intermediates included -- they are real tensors
    on this path) plus every intermediate write plus the output write."""
    words = 0
    for s in pipe.stages:
        words += traffic(s).total_reads
    words += sum(intermediate_words(pipe).values())
    words += output_words(pipe)
    return int(words)


def fused_traffic_words(pipe: Pipeline, block: int, *,
                        vmem_budget_words: int = VMEM_BYTES // 4) -> int:
    """Total HBM words moved by the fused megakernel: external reads of
    the fused IR (intermediates are VMEM-resident, contributing zero)
    plus the output write."""
    fused = fuse(pipe, block, vmem_budget_words=vmem_budget_words)
    return int(traffic(fused).total_reads) + output_words(pipe)


def fused_memory_plan(pipe: Pipeline, block: int, *,
                      vmem_budget_bytes: int = VMEM_BYTES):
    """VMEM plan of the fused kernel (stage scratch double-buffered)."""
    fused = fuse(pipe, block,
                 vmem_budget_words=vmem_budget_bytes // 4)
    return plan_memory(fused, vmem_budget_bytes=vmem_budget_bytes)


# --------------------------------------------------------------------------
# Lowering front-end (the `fused=True` path)
# --------------------------------------------------------------------------


def lower_pipeline(pipe: Pipeline, *, fused: bool = True, plan=None,
                   vmem_budget: Optional[int] = None,
                   cache=None) -> Callable:
    """Lower a pipeline to an executable callable.

    ``fused=True`` (default) runs joint DSE and emits the single-kernel
    Pallas lowering (``codegen_pallas.lower_fused_pipeline``);
    ``fused=False`` returns the per-stage oracle chain -- the
    pre-fusion semantics every fused kernel is validated against.
    """
    if not fused:
        return unfused_runner(pipe)
    from .codegen_pallas import lower_fused_pipeline
    return lower_fused_pipeline(pipe, plan=plan, vmem_budget=vmem_budget,
                                cache=cache)
