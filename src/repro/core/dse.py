"""Pattern-generic tile-size design space exploration (paper §4).

    "In future work, tile sizes for all pattern dimensions will instead
     be determined by the compiler through automated tile size selection
     using modeling and design space exploration."  (paper, §4)

This module is that subsystem, generalized beyond the GEMM template
(``repro.kernels.autotile`` is now a thin front-end over it).  Given any
*untiled* pattern program it:

  1. enumerates MXU/lane-aligned tile-size candidates for every named
     pattern domain (``tile_space``);
  2. applies the full tiling pipeline (``core.strip_mine.tile``) to each
     candidate and prices the tiled IR with the analytic cost model:
     main-memory traffic (``core.cost.traffic``) plus metapipeline
     overlap (``core.scheduling`` -> ``core.cost.metapipeline_time``);
  3. prunes candidates whose ``core.memory.plan_memory`` footprint
     exceeds the VMEM budget (the paper's BRAM-capacity compile check);
  4. returns the argmin as a ``TilePlan``, memoized in a persistent
     on-disk tuning cache keyed by (pattern signature, input tensor
     shapes, dtype, budget, device kind, calibration-profile hash).

The objective is lexicographic: fewest main-memory words first (the
quantity Fig. 5c/7 optimize), then modeled metapipelined seconds, then
*largest* on-chip footprint (prefer reuse when traffic ties).

Hybrid analytic->measured mode (``measure="top_k"``): the analytic
enumeration + VMEM pruning above *shortlists* candidates, the top-k
are actually lowered (``codegen_pallas.lower_for_timing``) and timed
on device (``core.measure``: warmup excluded, median-of-k,
device-keyed persistent timing DB), the measured argmin wins, and the
samples update the per-device cost-model calibration profile
(``core.calibrate``) that subsequent analytic pricing consumes.  Both
the winning plan and every measurement are cached, so a second
exploration does zero lowering and zero execution.  Setting
``REPRO_MEASURE=top_k`` turns the hybrid mode on for every
``auto_tile=True`` kernel and fused pipeline without code changes.

The bottom half of the module is a library of *proxy programs*: small
PPL models of each Pallas kernel's loop structure (flash attention, the
SSD chunked scan, filter+reduce, GroupByFold).  The kernels' ``auto_tile``
paths build these proxies and ask ``explore`` for block sizes, so every
kernel shares one exploration engine and one tuning cache.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import os
from typing import Dict, List, Optional, Tuple, Union


from . import calibrate, ir, resilience, telemetry
from . import measure as measure_mod
from .cost import HBM_BYTES_PER_S, VMEM_BYTES, stream_seconds, traffic
from .memory import plan_memory
# The exploration-option constants and the unified Options surface live
# in core.options (a leaf module); re-exported here because this module
# is their historical home and every consumer imports them from dse.
from .options import (DEPTHS, MAX_POINTS, MEASURE_REPEAT, MEASURE_WARMUP,
                      MXU, SUBLANE, TOP_K, UNSET, Options)
from .scheduling import build_schedule, model_speedup
from .strip_mine import insert_tile_copies, strip_mine, tile

# TPU min-tile row (sublane) multiples per dtype: the fp32 8-row tile
# becomes 16 rows for bf16/f16 and 32 for int8/fp8 (packed sublanes).
_DTYPE_SUBLANE = {
    "bfloat16": 16, "float16": 16, "half": 16,
    "int8": 32, "uint8": 32,
    "float8_e4m3fn": 32, "float8_e5m2": 32, "float8_e4m3b11fnuz": 32,
}


def dtype_sublane(dtype) -> int:
    """Sublane (row) alignment for a dtype's minimum TPU tile."""
    return _DTYPE_SUBLANE.get(str(dtype), SUBLANE)

# Cost/memory-model revision, folded into every tuning-cache key: plans
# priced under older model semantics (e.g. the pre-PR-2 single-buffer
# accounting for strided loads, the PR-2 chain-only pipeline pricing
# superseded by the DAG accounting, the pre-calibration pricing that
# ignored device identity and launch overhead, or the v4 fixed-depth-2
# pricing that predates the searched metapipeline buffer depth) must
# not be replayed as cache hits.  CI keys its persistent
# REPRO_DSE_CACHE on this string too.
MODEL_VERSION = 5


def _measure_mode(measure: Optional[str]) -> Optional[str]:
    """Validate a resolved ``measure`` value.  The ``REPRO_MEASURE``
    env opt-in is no longer consulted here: ``Options.from_env`` is the
    single env reader, merged by ``_resolve_options``."""
    if measure in (None, False, ""):
        return None
    if measure != "top_k":
        raise ValueError(f"measure={measure!r}; supported: None, 'top_k'")
    return measure


# legacy kwargs whose ``None`` default means "unset" (merged below
# Options / env); ``False`` stays explicit (measure/cache/profile off)
def _resolve_options(options: Optional[Options], **kw) -> Options:
    """Merge one exploration's option layers: explicit kwarg >
    ``options=Options(...)`` > ``Options.from_env()`` > defaults.
    Returns a fully resolved ``Options`` (no ``UNSET`` fields)."""
    explicit = Options(**{k: v for k, v in kw.items()
                          if v is not None and v is not UNSET})
    return Options.merged(explicit, options or Options(),
                          Options.from_env()).resolved()


def _resolve_profile(profile):
    """``None`` -> the device's persisted calibration profile (if any),
    ``False`` -> uncalibrated, else the given profile."""
    if profile is False:
        return None
    if profile is None:
        return calibrate.load_profile()
    return profile


# --------------------------------------------------------------------------
# Tile plans
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """DSE result: per-pattern tile sizes plus the model's accounting.

    ``depths`` maps each tiled pattern name to the metapipeline buffer
    depth the search selected for its stage-crossing buffers (one
    searched depth per plan, recorded per pattern so downstream
    consumers key it like ``sizes``); ``depth`` is the scalar view.
    """

    sizes: Dict[str, Tuple[int, ...]]
    traffic_words: int
    vmem_bytes: int
    modeled_seconds: float
    explored: int = 0        # candidates priced
    pruned: int = 0          # candidates rejected by the VMEM budget
    thinned: bool = False    # search space was capped (MAX_POINTS)
    cached: bool = False     # served from the tuning cache
    measured: bool = False   # winner backed by a real on-device timing
    measured_seconds: float = 0.0   # winner's median wall time
    timed: int = 0           # candidates actually lowered and timed
    depths: Dict[str, int] = dataclasses.field(default_factory=dict)
    warm_start: bool = False  # adapted from a tuned bucket (core.buckets)
    bucket: str = ""          # donor bucket signature (warm starts only)
    key: str = ""             # tuning-cache key (dse.explain provenance)

    @property
    def depth(self) -> int:
        """The plan's stage-buffer depth (2 when unrecorded)."""
        return next(iter(self.depths.values()), 2)

    def to_json(self) -> Dict:
        return {
            "sizes": {k: list(v) for k, v in self.sizes.items()},
            "depths": {k: int(v) for k, v in self.depths.items()},
            "traffic_words": int(self.traffic_words),
            "vmem_bytes": int(self.vmem_bytes),
            "modeled_seconds": float(self.modeled_seconds),
            "explored": int(self.explored),
            "pruned": int(self.pruned),
            "thinned": bool(self.thinned),
            "measured": bool(self.measured),
            "measured_seconds": float(self.measured_seconds),
            "timed": int(self.timed),
            "key": str(self.key),
        }

    @classmethod
    def from_json(cls, d: Dict) -> "TilePlan":
        return cls(sizes={k: tuple(v) for k, v in d["sizes"].items()},
                   depths={k: int(v)
                           for k, v in d.get("depths", {}).items()},
                   traffic_words=int(d["traffic_words"]),
                   vmem_bytes=int(d["vmem_bytes"]),
                   modeled_seconds=float(d["modeled_seconds"]),
                   explored=int(d.get("explored", 0)),
                   pruned=int(d.get("pruned", 0)),
                   thinned=bool(d.get("thinned", False)),
                   measured=bool(d.get("measured", False)),
                   measured_seconds=float(d.get("measured_seconds", 0.0)),
                   timed=int(d.get("timed", 0)),
                   key=str(d.get("key", "")),
                   cached=True)


# --------------------------------------------------------------------------
# Persistent tuning cache
# --------------------------------------------------------------------------


def default_cache_path() -> str:
    return measure_mod.cache_sibling_path("dse_cache.json",
                                          "REPRO_DSE_CACHE")


# reserved top-level keys in the cache document: the candidate
# quarantine and the shape-bucket donor index (core.buckets); plan keys
# are 32-hex digests, so no collision is possible
QUARANTINE_KEY = "__quarantine__"
BUCKETS_KEY = "__buckets__"


class TuningCache:
    """On-disk key -> TilePlan store, crash-safe.

    Persistence goes through ``core.resilience``'s store layer:
    checksummed JSON, atomic replace, lock-protected read-modify-write
    on every put (concurrent explorations merge instead of clobbering),
    and a truncated or corrupt file is quarantined to
    ``<path>.corrupt`` (a warning names it) with the cache rebuilding
    fresh -- the cache is an accelerator, never a correctness
    dependency.

    The same document persists the **candidate quarantine**: a
    candidate whose lowering, timing or certification failed is
    recorded under ``__quarantine__`` (keyed per device + interpret
    mode) and is never re-attempted by later explorations.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_cache_path()
        self._data: Optional[Dict[str, Dict]] = None

    def _load(self) -> Dict[str, Dict]:
        if self._data is None:
            self._data = resilience.load_store(self.path,
                                               label="DSE tuning cache")
        return self._data

    def _update(self, mutate) -> None:
        """Apply ``mutate(data)`` to the in-memory view AND, under the
        file lock, to the freshly re-read on-disk state -- entries a
        concurrent process wrote between our load and this put
        survive, and our view keeps its own entries even when the
        write fails (read-only FS)."""
        mine = self._load()
        mutate(mine)
        disk = resilience.locked_update(self.path, mutate,
                                        label="DSE tuning cache",
                                        prefix=".dse_cache.")
        q = {**mine.get(QUARANTINE_KEY, {}),
             **disk.get(QUARANTINE_KEY, {})}
        merged = {**mine, **disk}
        if q:
            merged[QUARANTINE_KEY] = q
        # bucket index: two-level nested merge (family -> bucket sig ->
        # donor entry), disk winning per bucket like plans do
        bk = dict(mine.get(BUCKETS_KEY, {}))
        for fam, ent in disk.get(BUCKETS_KEY, {}).items():
            bk[fam] = {**bk.get(fam, {}), **ent}
        if bk:
            merged[BUCKETS_KEY] = bk
        self._data = merged

    def get(self, key: str, cls=None) -> Optional["TilePlan"]:
        """Fetch a plan; ``cls`` selects the plan dataclass (default
        ``TilePlan``; ``PipelinePlan`` for joint pipeline plans)."""
        d = self._load().get(key)
        if d is None:
            return None
        try:
            return (cls or TilePlan).from_json(d)
        except (KeyError, TypeError, ValueError):
            return None

    def put(self, key: str, plan) -> None:
        doc = plan.to_json()
        self._update(lambda data: data.__setitem__(key, doc))

    def quarantine(self, key: str, kind: str, detail: str = "") -> None:
        """Persist a failed candidate so it is never re-attempted."""
        entry = {"kind": kind, "detail": detail[:500]}

        def mutate(data: Dict) -> None:
            data.setdefault(QUARANTINE_KEY, {})[key] = entry

        self._update(mutate)

    def quarantined(self, key: str) -> Optional[Dict]:
        """The quarantine record for ``key`` ({"kind", "detail"}), or
        None when the candidate has never failed."""
        q = self._load().get(QUARANTINE_KEY)
        entry = q.get(key) if isinstance(q, dict) else None
        return entry if isinstance(entry, dict) else None

    def bucket_entries(self, family: str) -> Dict[str, Dict]:
        """The shape-bucket donor index for one pattern family:
        {bucket signature: {"kind", "domains", "plan"}}
        (``core.buckets`` owns the format)."""
        bk = self._load().get(BUCKETS_KEY)
        fam = bk.get(family) if isinstance(bk, dict) else None
        return fam if isinstance(fam, dict) else {}

    def bucket_put(self, family: str, sig: str, entry: Dict) -> None:
        """Register a tuned plan as its bucket's warm-start donor."""
        def mutate(data: Dict) -> None:
            data.setdefault(BUCKETS_KEY, {}).setdefault(
                family, {})[sig] = entry

        self._update(mutate)

    def clear(self) -> None:
        self._data = {}
        try:
            os.unlink(self.path)
        except OSError:
            pass


def _resolve_cache(cache: Union[None, bool, str, "TuningCache"]
                   ) -> Optional[TuningCache]:
    """``None`` -> default on-disk cache, path/TuningCache -> that cache,
    ``False`` -> no caching."""
    if cache is False:
        return None
    if cache is None:
        return TuningCache()
    if isinstance(cache, str):
        return TuningCache(cache)
    return cache


def _reads_sig(p: ir.Pattern, enc: int = 0) -> Tuple:
    """Access descriptors in pre-order: (src, window, affine, index map).

    ``ir.signature`` covers domains/nesting/loads but not reads, and an
    untiled program carries all its shape information in reads -- two
    programs differing only in an access window must not share a key.
    Index maps are probed best-effort (non-affine maps hash as opaque).
    """
    from .affine import AffineMap

    out: List = []
    stack = enc + len(p.domain)
    for a in p.accesses:
        src = a.src.name if isinstance(a.src, ir.Tensor) \
            else type(a.src).__name__
        if isinstance(a.index_map, AffineMap):
            m: object = (a.index_map.base, a.index_map.mat)
        else:
            try:
                amap = AffineMap.probe(a.index_map, stack)
                m = (amap.base, amap.mat)
            except (TypeError, ValueError, IndexError):
                # unit probing a non-affine / non-integer map fails in
                # exactly these ways; anything else is a real bug in
                # the map and must surface, not hash as opaque
                m = "nonaffine"
        out.append((src, tuple(a.window), a.affine, m))
        if isinstance(a.src, ir.Pattern):
            out.append(_reads_sig(a.src, stack))
    if p.inner is not None:
        out.append(_reads_sig(p.inner, stack))
    return tuple(out)


def _key_context(device: Optional[str],
                 profile_hash: Optional[str]) -> Tuple[str, str]:
    """(device kind, calibration-profile hash) folded into every cache
    key: a plan tuned on one device, or priced under one calibration,
    must not be replayed on another device / after recalibration.
    Explicit values (including ``""`` to opt out, e.g. for timing-DB
    keys that identify the *computation*, not its pricing) pass through.
    """
    if device is None:
        device = measure_mod.device_kind()
    if profile_hash is None:
        profile_hash = calibrate.active_profile_hash(device)
    return device, profile_hash


def pattern_key(p: ir.Pattern, *,
                vmem_budget: int = VMEM_BYTES,
                align: int = MXU,
                extra: Tuple = (),
                device: Optional[str] = None,
                profile_hash: Optional[str] = None) -> str:
    """Tuning-cache key: structural signature + access descriptors +
    input shapes/dtypes + exploration constraints + device kind +
    calibration-profile hash.

    Any change to the pattern tree (domains, nesting, reads, tensor
    shapes or dtypes), to the constraints, to the device, or to the
    active calibration changes the key, so cached plans invalidate
    automatically instead of going stale.
    """
    device, profile_hash = _key_context(device, profile_hash)
    inputs = tuple((t.name, tuple(t.shape), t.dtype)
                   for t in ir.inputs_of(p))
    raw = repr((MODEL_VERSION, device, profile_hash,
                ir.signature(p), _reads_sig(p), inputs,
                int(vmem_budget), int(align), tuple(extra)))
    return hashlib.sha256(raw.encode()).hexdigest()[:32]


# --------------------------------------------------------------------------
# Candidate enumeration
# --------------------------------------------------------------------------


def axis_candidates(extent: int, align: int = MXU, *,
                    sublane: int = 1) -> List[int]:
    """Divisors of ``extent`` that are multiples of both
    ``min(align, extent)`` and the dtype ``sublane``, falling back to
    the full extent.

    Divisor (not power-of-two) enumeration admits ragged tiles -- a
    96-wide domain offers 24/48 in addition to the 8/16/32 ladder --
    while the multiple-of-align floor keeps every candidate expressible
    on the hardware (a non-128-multiple lane tile is not).  ``sublane``
    is the dtype row multiple (8 fp32 / 16 bf16 / 32 int8,
    ``dtype_sublane``).  The whole extent is always a candidate: there
    is nothing left to misalign against.
    """
    floor = min(align, extent)
    divs: List[int] = []
    d = 1
    while d * d <= extent:
        if extent % d == 0:
            divs.append(d)
            if d != extent // d:
                divs.append(extent // d)
        d += 1
    out = sorted(c for c in divs
                 if c == extent
                 or (c % floor == 0 and c % sublane == 0))
    return out or [extent]


def tile_space(p: ir.Pattern, *, align: int = MXU
               ) -> Dict[str, List[Tuple[int, ...]]]:
    """Per-named-pattern candidate tile tuples for every (untiled) domain.

    The full design space is the cross product over patterns; patterns
    that already carry a strided domain are left alone.  Candidate rows
    are aligned to the pattern dtype's sublane multiple
    (``dtype_sublane``), not the fp32-only 8-row assumption.
    """
    space: Dict[str, List[Tuple[int, ...]]] = {}
    for q in ir.walk(p):
        if q.strided or not q.domain or q.name in space:
            continue
        sub = dtype_sublane(q.dtype)
        per_dim = [axis_candidates(d, align, sublane=sub)
                   for d in q.domain]
        space[q.name] = [tuple(c) for c in itertools.product(*per_dim)]
    return space


def _thin(space: Dict[str, List[Tuple[int, ...]]],
          max_points: int) -> Tuple[Dict[str, List[Tuple[int, ...]]], bool]:
    """Halve the densest axis list (keeping endpoints) until the cross
    product is within budget.  Returns (space, was_thinned)."""
    def total(s):
        t = 1
        for v in s.values():
            t *= len(v)
        return t

    thinned = False
    space = {k: list(v) for k, v in space.items()}
    while total(space) > max_points:
        name = max(space, key=lambda k: len(space[k]))
        v = space[name]
        if len(v) <= 2:
            break
        space[name] = v[::2] if v[-1] == v[::2][-1] else v[::2] + [v[-1]]
        thinned = True
    return space, thinned


# --------------------------------------------------------------------------
# Pricing
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Priced:
    sizes: Dict[str, Tuple[int, ...]]
    traffic_words: int
    vmem_bytes: int
    modeled_seconds: float           # uncalibrated analytic prediction
    calibrated_seconds: float = -1.0  # profile-adjusted (== analytic
    steps: int = 1                    # when uncalibrated); grid steps
    depth: int = 2                    # metapipeline buffer depth

    def __post_init__(self):
        if self.calibrated_seconds < 0:
            object.__setattr__(self, "calibrated_seconds",
                               self.modeled_seconds)


def grid_steps(p: ir.Pattern, sizes: Dict[str, Tuple[int, ...]]) -> int:
    """Kernel grid steps the tiled program executes: the product of
    (extent / tile) over every tiled domain.  The trip count the
    calibration model charges per-pattern launch overhead against."""
    steps = 1
    for q in ir.walk(p):
        if q.name not in sizes or not q.domain:
            continue
        for d, s in zip(q.domain, sizes[q.name]):
            steps *= max(1, -(-d // max(int(s), 1)))
    return steps


def _tile_ir(p: ir.Pattern, sizes: Dict[str, Tuple[int, ...]],
             vmem_budget_words: int) -> ir.Pattern:
    try:
        return tile(p, sizes, vmem_budget_words=vmem_budget_words)
    except resilience.EXPECTED_ERRORS as e:
        # interchange/lift may not apply to every proxy shape; the
        # strip-mine + copy-insertion core always does.  Recorded once
        # per pattern (price() calls this per candidate) so the
        # degradation is observable without spamming the event log;
        # real bugs (AttributeError etc.) propagate.
        resilience.record_once(
            "tile", resilience.classify(e),
            f"{type(p).__name__}:{p.name}", "fallback",
            f"tile() failed ({e}); strip-mine+copies fallback")
        return insert_tile_copies(strip_mine(p, sizes),
                                  vmem_budget_words=vmem_budget_words)


def price(p: ir.Pattern, sizes: Dict[str, Tuple[int, ...]], *,
          vmem_budget: int = VMEM_BYTES,
          bytes_per_word: int = 4,
          profile=False,
          depth: int = 2) -> Optional[Priced]:
    """Tile ``p`` with ``sizes`` and price it; None if it busts VMEM.

    Modeled seconds = HBM stream time of the tiled IR's main-memory
    reads, scaled by the metapipeline time ratio of its schedule
    (``metapipeline_time`` steady state vs. sequential).  ``depth`` is
    the stage-buffer depth the schedule and VMEM plan are built with:
    the plan charges ``depth x`` bytes per stage-crossing buffer (so a
    deep candidate can bust VMEM where the shallow one fits) and the
    time model charges whatever DMA issue latency ``depth - 1``
    iterations of lookahead cannot hide.  With a calibration profile
    (``profile``: None -> the device's persisted one, False ->
    uncalibrated), ``calibrated_seconds`` reprices the same overlapped
    stream at the *measured* effective bandwidth plus the per-pattern
    launch overhead per grid step.
    """
    prof = _resolve_profile(profile)
    t = _tile_ir(p, sizes, vmem_budget // bytes_per_word)
    plan = plan_memory(t, vmem_budget_bytes=vmem_budget, depth=depth)
    if not plan.fits:
        return None
    # an affine tensor read left in place means its tile copy would not
    # fit on-chip (insert_tile_copies' streaming fallback): over-VMEM
    for q in ir.walk(t):
        for a in q.accesses:
            if isinstance(a.src, ir.Tensor) and a.affine:
                return None
    tr = traffic(t)
    seconds = stream_seconds(tr.total_reads, bytes_per_word=bytes_per_word)
    mp = build_schedule(t, vmem_budget // bytes_per_word, depth=depth)
    if mp is not None:
        body_words = sum(s.words for s in mp.stages if s.kind == "body")
        seq, pipe, _ = model_speedup(mp, flops_per_body=body_words * 100.0)
        if seq > 0 and pipe > 0:
            # pipe/seq < 1 is the overlap speedup; > 1 means exposed
            # DMA latency dominates the shallow pipeline -- both priced
            seconds *= pipe / seq
    steps = grid_steps(p, sizes)
    calibrated = calibrate.predicted_seconds(
        type(p).__name__, seconds * HBM_BYTES_PER_S, steps, profile=prof)
    return Priced(dict(sizes), tr.total_reads, plan.total_bytes, seconds,
                  calibrated, steps, depth=depth)


def _better(a: Priced, b: Optional[Priced]) -> bool:
    """Lexicographic: traffic, then (calibrated) modeled time, then
    shallowest depth, then prefer reuse."""
    if b is None:
        return True
    return _rank_key(a) < _rank_key(b)


def _rank_key(a: Priced) -> Tuple:
    # depth breaks seconds ties BEFORE the -vmem reuse term: once the
    # exposed-latency term saturates, deeper variants tie on seconds
    # and their larger footprint must not win via the reuse preference
    return (a.traffic_words, a.calibrated_seconds, a.depth, -a.vmem_bytes)


# --------------------------------------------------------------------------
# Exploration
# --------------------------------------------------------------------------


def shortlist(p: ir.Pattern, *,
              vmem_budget: int = VMEM_BYTES,
              align: int = MXU,
              space: Optional[Dict[str, List[Tuple[int, ...]]]] = None,
              max_points: int = MAX_POINTS,
              profile=False,
              depths: Tuple[int, ...] = DEPTHS
              ) -> Tuple[List[Priced], bool, int, int]:
    """Analytic enumeration + VMEM pruning, every feasible candidate
    priced and sorted best-first by the lexicographic objective.

    The candidate space is the cross product of tile sizes x ``depths``
    (metapipeline buffer depths): a deep variant of a tile that would
    bust VMEM is pruned exactly like an oversized tile.  Returns
    ``(candidates, thinned, explored, pruned)``; the plain analytic
    argmin is ``candidates[0]``, the hybrid mode lowers and times
    ``candidates[:top_k]``.
    """
    prof = _resolve_profile(profile)
    if space is None:
        space = tile_space(p, align=align)
    space, thinned = _thin(space, max_points)
    names = sorted(space)

    cands: List[Priced] = []
    explored = pruned = 0
    for combo in itertools.product(*(space[n] for n in names)):
        sizes = dict(zip(names, combo))
        for d in depths:
            priced = price(p, sizes, vmem_budget=vmem_budget,
                           profile=prof if prof is not None else False,
                           depth=d)
            explored += 1
            if priced is None:
                pruned += 1
                continue
            cands.append(priced)
    cands.sort(key=_rank_key)
    return cands, thinned, explored, pruned


@dataclasses.dataclass(frozen=True)
class CandidateTiming:
    """One shortlisted candidate, actually lowered and timed."""

    sizes: Dict[str, Tuple[int, ...]]
    traffic_words: int
    vmem_bytes: int
    analytic_seconds: float      # uncalibrated model prediction
    calibrated_seconds: float    # profile-adjusted model prediction
    steps: int
    measurement: measure_mod.Measurement
    lowering: str                # "pallas" | "oracle" | "cached"
    depth: int = 2               # metapipeline buffer depth


def _workload_tag(p: ir.Pattern) -> str:
    shapes = "+".join(f"{t.name}:{'x'.join(map(str, t.shape))}"
                      for t in ir.inputs_of(p))
    return f"{type(p).__name__}:{p.name}:{shapes}"


def _top_distinct_sizes(cands: List[Priced], k: int) -> List[Priced]:
    """Best-first prefix of ``cands`` with at most one entry per tile
    assignment.  Depth variants of one tile execute identically under
    the single-pattern templates (the Mosaic pipeliner owns the
    BlockSpec buffering), so timing them separately would spend the
    whole top-k on copies of a single measurement; keeping the best-
    ranked depth per sizes times ``k`` genuinely distinct kernels."""
    out: List[Priced] = []
    seen = set()
    for c in cands:
        sig = tuple(sorted((n, tuple(v)) for n, v in c.sizes.items()))
        if sig in seen:
            continue
        seen.add(sig)
        out.append(c)
        if len(out) >= k:
            break
    return out


def _time_candidates(p: ir.Pattern, top: List[Priced], *,
                     vmem_budget: int, align: int,
                     timing_db, warmup: int, repeat: int,
                     policy: Optional[resilience.Policy] = None,
                     cache: Optional[TuningCache] = None
                     ) -> List[CandidateTiming]:
    """Lower + time shortlisted candidates (timing-DB memoized).

    Each lower+time runs under the resilience policy's deadline with
    transient retry; an expected failure (no template, numeric blowup,
    injected fault, deadline miss) classifies the candidate, records a
    structured event, and -- when ``cache`` is given -- quarantines it
    so no later exploration re-attempts the same crash.  Unexpected
    exceptions still propagate: a real bug must surface.
    """
    from .codegen_pallas import lower_for_timing

    pol = resilience.resolve_policy(policy)
    out: List[CandidateTiming] = []
    for cand in top:
        sizes_sig = tuple(sorted((k, tuple(v))
                                 for k, v in cand.sizes.items()))
        # identifies the computation, not its pricing: no device /
        # profile-hash component (TimingDB adds the device itself).
        # Depth is deliberately absent: single-pattern lowerings
        # delegate buffering to the Pallas pipeliner, so every depth
        # variant of one tile assignment is the same executable.
        key = pattern_key(p, vmem_budget=vmem_budget, align=align,
                          extra=("timing", sizes_sig),
                          device="", profile_hash="")
        qkey = "time|" + measure_mod.TimingDB.full_key(key)
        if cache is not None:
            q = cache.quarantined(qkey)
            if q is not None:
                resilience.record_once(
                    "time", q.get("kind", "unknown"), qkey, "skipped",
                    "previously quarantined candidate not re-attempted")
                continue
        how = ["cached"]

        def make_fn(sizes=cand.sizes, how=how):
            fn, how[0] = lower_for_timing(p, sizes,
                                          vmem_budget=vmem_budget)
            return fn

        try:
            m = resilience.call_guarded(
                lambda: measure_mod.timed(key, make_fn, db=timing_db,
                                          warmup=warmup, repeat=repeat),
                stage="time", key=qkey, policy=pol)
        except resilience.CandidateFailure as e:
            resilience.record("time", e.kind, qkey, "quarantined",
                              e.detail)
            if cache is not None:
                cache.quarantine(qkey, e.kind, e.detail)
            continue
        out.append(CandidateTiming(
            sizes=dict(cand.sizes), traffic_words=cand.traffic_words,
            vmem_bytes=cand.vmem_bytes,
            analytic_seconds=cand.modeled_seconds,
            calibrated_seconds=cand.calibrated_seconds,
            steps=cand.steps, measurement=m, lowering=how[0],
            depth=cand.depth))
    return out


def _accuracy_gauges(kind: str, pairs: List[Tuple[float, float]]) -> None:
    """Model-accuracy gauges per pattern family, from one measured
    shortlist's (calibrated prediction, measured median) pairs:
    ``model.drift.<kind>`` the mean relative |predicted - measured| /
    measured, ``model.spearman.<kind>`` the rank correlation of the
    analytic ordering against the measured one.  Always-on (gauges are
    cheap scalars): ``benchmarks/check_regression.py`` prints them next
    to the gate output without needing ``REPRO_TRACE``."""
    if not pairs:
        return
    drift = sum(abs(p - m) / max(m, 1e-12) for p, m in pairs) / len(pairs)
    telemetry.gauge(f"model.drift.{kind}", drift)
    if len(pairs) >= 2:
        telemetry.gauge(f"model.spearman.{kind}",
                        measure_mod.spearman([p for p, _ in pairs],
                                             [m for _, m in pairs]))


def _observe(p_kind: str, workload: str,
             timings: List[CandidateTiming]) -> None:
    samples = [calibrate.Sample(
        workload=workload, kind=p_kind,
        stream_bytes=t.analytic_seconds * HBM_BYTES_PER_S,
        steps=t.steps, measured_s=t.measurement.median_s,
        key=f"{workload}|{sorted(t.sizes.items())}")
        for t in timings]
    if samples:
        calibrate.observe(samples)
    _accuracy_gauges(p_kind, [(t.calibrated_seconds,
                               t.measurement.median_s)
                              for t in timings])


def _record_plan(plan, *, source: str, **extra) -> None:
    """Stash a plan's exploration provenance for ``explain`` (tracing
    only; the record store is a bounded LRU in ``core.telemetry``).
    Merges into any existing record under the same key: a cache hit
    updates ``source`` without losing the original exploration's rank
    tables, and a warm start's ``retune_tag`` survives the background
    re-tune recording its own exploration under the promoted key."""
    if not telemetry.enabled() or not plan.key:
        return
    prev = telemetry.get_record("plan", plan.key)
    payload = dict(prev) if isinstance(prev, dict) else {}
    payload.update({"source": source, **extra})
    telemetry.put_record("plan", plan.key, payload)


def measured_shortlist(p: ir.Pattern, *,
                       top_k: int = TOP_K,
                       vmem_budget: int = VMEM_BYTES,
                       align: int = MXU,
                       space: Optional[Dict[str, List[Tuple[int, ...]]]]
                       = None,
                       max_points: int = MAX_POINTS,
                       profile=None,
                       timing_db=None,
                       warmup: int = MEASURE_WARMUP,
                       repeat: int = MEASURE_REPEAT,
                       calibrate_update: bool = True,
                       policy: Optional[resilience.Policy] = None,
                       cache: Union[None, bool, str, TuningCache] = False
                       ) -> List[CandidateTiming]:
    """Hybrid step as a library call: analytic shortlist, lower + time
    the top-k, optionally fold the samples into the device calibration
    profile.  ``benchmarks/run.py --measure`` builds its analytic-vs-
    measured rank-correlation table from exactly these records.

    ``policy`` bounds each lower+time with a deadline and transient
    retry; ``cache`` (default off for the library call) enables the
    persistent candidate quarantine shared with ``explore``.
    """
    cands, _, _, _ = shortlist(p, vmem_budget=vmem_budget, align=align,
                               space=space, max_points=max_points,
                               profile=profile)
    timings = _time_candidates(p, _top_distinct_sizes(cands,
                                                      max(top_k, 1)),
                               vmem_budget=vmem_budget, align=align,
                               timing_db=timing_db, warmup=warmup,
                               repeat=repeat, policy=policy,
                               cache=_resolve_cache(cache))
    if calibrate_update:
        _observe(type(p).__name__, _workload_tag(p), timings)
    return timings


def explore(p: ir.Pattern, *,
            vmem_budget: Optional[int] = None,
            align: Optional[int] = None,
            space: Optional[Dict[str, List[Tuple[int, ...]]]] = None,
            cache: Union[None, bool, str, TuningCache] = None,
            max_points: Optional[int] = None,
            measure: Optional[str] = None,
            top_k: Optional[int] = None,
            timing_db=None,
            profile=None,
            warmup: Optional[int] = None,
            repeat: Optional[int] = None,
            depths: Optional[Tuple[int, ...]] = None,
            policy: Optional[resilience.Policy] = None,
            bucketing: Optional[bool] = None,
            options: Optional[Options] = None) -> TilePlan:
    """Design-space exploration over tile sizes AND metapipeline buffer
    depths for any pattern program.

    ``p`` is the *untiled* program.  ``cache`` selects the tuning cache:
    ``None`` -> the default on-disk cache, a path or ``TuningCache`` ->
    that cache, ``False`` -> no caching.  ``depths`` is the set of
    stage-buffer depths enumerated per tile candidate (default
    ``DEPTHS = (2, 3, 4)``): each (sizes, depth) pair is priced with
    ``depth x`` VMEM charged per stage-crossing buffer and the exposed
    DMA latency the depth cannot hide; ties in modeled seconds break
    toward the shallowest depth.  The winner's depth is recorded on
    ``TilePlan.depths``.  Raises ``ValueError`` when no candidate fits
    the VMEM budget.

    ``measure="top_k"`` (or ``REPRO_MEASURE=top_k``) switches to hybrid
    analytic->measured mode: the analytic shortlist's top ``top_k``
    candidates (distinct tile assignments; depth variants of one tile
    share a measurement because the single-pattern templates delegate
    buffering to the Pallas pipeliner) are lowered
    (``codegen_pallas.lower_for_timing``) and timed
    (median-of-``repeat``, ``warmup`` excluded, memoized in the
    device-keyed ``timing_db``), the measured argmin wins, and the
    samples recalibrate the device profile before the plan is cached --
    so a second call is a pure cache hit: zero lowering, zero execution.

    The measured path is fault-tolerant (``core.resilience``): each
    lower+time runs under ``policy``'s deadline with transient retry,
    failing candidates are quarantined in the tuning cache (never
    re-attempted), and the measured winner is *certified* against the
    ``codegen_jax`` oracle before promotion -- a winner that times well
    but computes wrong numbers is quarantined and the next-fastest
    certified candidate wins instead.  When every measured candidate
    fails, the analytic argmin ships (recorded as a fallback event);
    ``explore`` never raises for a candidate-level failure.

    Every kwarg can instead arrive packed in ``options=Options(...)``;
    explicit kwargs win over the options object, which wins over the
    ``REPRO_*`` env vars (``Options.from_env``), which win over the
    defaults.  ``bucketing=True`` adds the shape-bucketed mode
    (``core.buckets``): a cold shape whose pattern family has tuned
    buckets returns a warm-start plan immediately (nearest bucket's
    tiles re-fitted, zero lowering) while a background re-tune --
    deadline-bounded by ``policy`` -- explores the exact shape and
    promotes its certified winner into the cache.
    """
    o = _resolve_options(options, vmem_budget=vmem_budget, align=align,
                         cache=cache, max_points=max_points,
                         measure=measure, top_k=top_k,
                         timing_db=timing_db, profile=profile,
                         warmup=warmup, repeat=repeat, depths=depths,
                         policy=policy, bucketing=bucketing)
    if o.trace:
        telemetry.enable()
    with telemetry.span("dse.explore", kind=type(p).__name__,
                        pattern=p.name) as sp:
        return _explore_body(p, space, o, sp)


def _explore_body(p: ir.Pattern, space, o: Options, sp) -> TilePlan:
    vmem_budget, align = o.vmem_budget, o.align
    max_points, measure, top_k = o.max_points, o.measure, o.top_k
    timing_db, profile = o.timing_db, o.profile
    warmup, repeat, depths, policy = (o.warmup, o.repeat, o.depths,
                                      o.policy)
    tc = _resolve_cache(o.cache)

    space_was_default = space is None
    if space is None:
        space = tile_space(p, align=align)
    space, thinned = _thin(space, max_points)
    names = sorted(space)

    # the key covers the *resolved* candidate space: a caller-restricted
    # or thinned exploration must not share cache entries with a full
    # one, nor a measured exploration with a purely analytic one, nor
    # a depth-restricted exploration with the default-depths one
    space_sig = tuple((n, tuple(space[n])) for n in names)
    extra = space_sig + (("depths",) + tuple(int(d) for d in depths),) \
        + ((("measure", measure, int(top_k)),) if measure else ())

    def key_now() -> str:
        return pattern_key(p, vmem_budget=vmem_budget, align=align,
                           extra=extra)

    # explicit ``space=`` pins the candidate set to the caller's shape:
    # a donor bucket's plan would not be comparable, so bucketing only
    # engages for the default space
    bucketing_on = o.bucketing and tc is not None and space_was_default
    if bucketing_on:
        from . import buckets as buckets_mod

    if tc is not None:
        hit = tc.get(key_now())
        if hit is not None:
            if bucketing_on:
                buckets_mod.note("exact_hits")
            telemetry.count("dse.cache_hits")
            hit = dataclasses.replace(hit, key=key_now())
            sp.set(source="cache")
            _record_plan(hit, source="cache")
            return hit

    if bucketing_on:
        warm = buckets_mod.warm_start_tile(p, tc, vmem_budget=vmem_budget,
                                           align=align)
        if warm is not None:
            buckets_mod.note("warm_hits")
            pol = resilience.resolve_policy(policy)
            # cache=False: the re-tune must not write the cache itself
            # -- only its *certified* winner is promoted, below
            retune_opts = dataclasses.replace(o, bucketing=False,
                                              cache=False)
            tag = "tile|" + key_now()

            def _retune() -> TilePlan:
                return explore(p, options=retune_opts)

            def _certify(plan: TilePlan):
                return resilience.certify_guarded(
                    lambda: resilience.certify_tile_plan(
                        p, plan.sizes, vmem_budget=vmem_budget),
                    key="retune|" + tag, policy=pol)

            def _promote(plan: TilePlan) -> None:
                # key recomputed at promotion time: the background
                # explore may have refreshed the calibration profile
                tc.put(key_now(), plan)
                buckets_mod.record_tile(p, plan, tc,
                                        vmem_budget=vmem_budget,
                                        align=align)

            buckets_mod.schedule_retune(tag, _retune, certify=_certify,
                                        promote=_promote, policy=pol)
            warm = dataclasses.replace(warm, key=key_now())
            sp.set(source="warm_start", bucket=warm.bucket)
            _record_plan(warm, source="warm_start", bucket=warm.bucket,
                         retune_tag=tag)
            return warm
        buckets_mod.note("misses")

    # space already thinned above: keep the outer flag (re-thinning an
    # already-thinned space is a no-op and would report False)
    with telemetry.span("dse.shortlist", thinned=thinned) as ssp:
        cands, _, explored, pruned = shortlist(
            p, vmem_budget=vmem_budget, align=align, space=space,
            max_points=max_points, profile=profile, depths=depths)
        ssp.set(explored=explored, pruned=pruned, feasible=len(cands))
    if not cands:
        raise ValueError(
            f"DSE: no tile candidate fits VMEM budget {vmem_budget} B "
            f"({explored} candidates over {names})")

    measured_s = 0.0
    timed_n = 0
    best = cands[0]
    prov_measured: List[Dict] = []
    prov_cert: List[Dict] = []
    n_short = n_timed = 0
    if measure == "top_k":
        pol = resilience.resolve_policy(policy)
        with telemetry.span("dse.measure", top_k=int(top_k)) as msp:
            top = _top_distinct_sizes(cands, max(top_k, 1))
            n_short = len(top)
            timings = _time_candidates(p, top, vmem_budget=vmem_budget,
                                       align=align, timing_db=timing_db,
                                       warmup=warmup, repeat=repeat,
                                       policy=pol, cache=tc)
            _observe(type(p).__name__, _workload_tag(p), timings)
            ranked = sorted(timings,
                            key=lambda t: (t.measurement.median_s,
                                           t.traffic_words, t.depth,
                                           -t.vmem_bytes))
            prov_measured = [
                {"sizes": {k: list(v) for k, v in t.sizes.items()},
                 "depth": int(t.depth),
                 "median_s": float(t.measurement.median_s),
                 "lowering": t.lowering} for t in ranked]
            n_timed = len(timings)
            msp.set(shortlisted=n_short, timed=n_timed)
            for win in ranked:
                if pol.certify:
                    sig = tuple(sorted((k, tuple(v))
                                       for k, v in win.sizes.items()))
                    ckey = "certify|" + measure_mod.TimingDB.full_key(
                        pattern_key(p, vmem_budget=vmem_budget,
                                    align=align, extra=("certify", sig),
                                    device="", profile_hash=""))
                    if tc is not None \
                            and tc.quarantined(ckey) is not None:
                        # failed certification in a past run
                        prov_cert.append(
                            {"sizes": {k: list(v)
                                       for k, v in win.sizes.items()},
                             "ok": False, "reason": "quarantined"})
                        continue
                    ok, reason = resilience.certify_guarded(
                        lambda w=win: resilience.certify_tile_plan(
                            p, w.sizes, vmem_budget=vmem_budget),
                        key=ckey, policy=pol)
                    prov_cert.append(
                        {"sizes": {k: list(v)
                                   for k, v in win.sizes.items()},
                         "ok": bool(ok), "reason": reason})
                    if not ok:
                        resilience.record("certify", "certify-failed",
                                          ckey, "quarantined", reason)
                        if tc is not None:
                            tc.quarantine(ckey, "certify-failed", reason)
                        continue
                best = Priced(win.sizes, win.traffic_words,
                              win.vmem_bytes, win.analytic_seconds,
                              win.calibrated_seconds, win.steps,
                              depth=win.depth)
                measured_s = win.measurement.median_s
                timed_n = len(timings)
                break
            else:
                # every shortlisted candidate failed timing or
                # certification: the analytic argmin ships, uncertified
                # measured data never does
                resilience.record(
                    "explore", "no-measured-winner", _workload_tag(p),
                    "fallback",
                    f"{len(timings)} timed, 0 certified; analytic "
                    "argmin promoted instead")

    # key recomputed AFTER the calibration update: the next call
    # prices under the new profile hash and must hit this entry
    final_key = key_now()
    plan = TilePlan(sizes={k: tuple(v) for k, v in best.sizes.items()},
                    depths={k: int(best.depth) for k in best.sizes},
                    traffic_words=best.traffic_words,
                    vmem_bytes=best.vmem_bytes,
                    modeled_seconds=best.calibrated_seconds,
                    explored=explored, pruned=pruned, thinned=thinned,
                    measured=timed_n > 0, measured_seconds=measured_s,
                    timed=timed_n, key=final_key)
    if tc is not None:
        tc.put(final_key, plan)
        if bucketing_on:
            buckets_mod.record_tile(p, plan, tc, vmem_budget=vmem_budget,
                                    align=align)
    sp.set(source="explored", explored=explored, pruned=pruned,
           timed=timed_n)
    _record_plan(
        plan, source="explored",
        enumerated=explored,
        pruned={"vmem": pruned,
                "dominated": (max(len(cands) - n_short, 0)
                              if measure == "top_k" else 0),
                "measure_failures": max(n_short - n_timed, 0)},
        analytic_ranks=[
            {"sizes": {k: list(v) for k, v in c.sizes.items()},
             "depth": int(c.depth),
             "traffic_words": int(c.traffic_words),
             "calibrated_seconds": float(c.calibrated_seconds)}
            for c in cands[:max(int(top_k), 3)]],
        measured_ranks=prov_measured,
        certification=prov_cert)
    return plan


# --------------------------------------------------------------------------
# Joint exploration for pipelines (fused multi-pattern programs)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """Joint DSE result for a pipeline DAG: streaming tiles plus the
    fusion grouping.

    ``groups`` are contiguous ``[start, end)`` ranges over the
    pipeline's *topological* stage order; a single group spanning the
    whole DAG means fully fused (intermediates are VMEM-resident,
    inter-stage HBM traffic = 0).  More than one group is the split
    fallback: every intermediate crossing a group boundary round-trips
    HBM, the cuts are the cheapest under the traffic model, and each
    group carries its own streaming tile in ``group_blocks`` (the split
    paths need not share a block size).  ``block`` is the first group's
    tile -- for a fused plan, the tile of the whole megakernel.

    ``depths`` (parallel to ``group_blocks``) records each group's
    searched metapipeline buffer depth: the stage scratch and input
    blocks of that group's megakernel rotate ``depth`` copies
    (``codegen_pallas.lower_fused_dag``), priced against the latency
    they hide (``cost.metapipeline_time``).  ``depth`` is the first
    group's value.
    """

    block: int
    groups: Tuple[Tuple[int, int], ...]
    traffic_words: int            # fused plan: HBM reads + writes
    unfused_traffic_words: int    # every intermediate round-trips HBM
    vmem_bytes: int               # max per-group footprint
    modeled_seconds: float
    group_blocks: Tuple[int, ...] = ()
    explored: int = 0
    pruned: int = 0
    cached: bool = False
    measured: bool = False          # winner backed by a real timing
    measured_seconds: float = 0.0   # winner's median wall time
    timed: int = 0                  # candidates lowered and timed
    depths: Tuple[int, ...] = ()    # per-group stage-buffer depth
    warm_start: bool = False        # adapted from a tuned bucket
    bucket: str = ""                # donor bucket signature
    key: str = ""                   # tuning-cache key (dse.explain)

    def __post_init__(self):
        if not self.group_blocks:
            object.__setattr__(self, "group_blocks",
                               (self.block,) * len(self.groups))
        if not self.depths:
            object.__setattr__(self, "depths", (2,) * len(self.groups))

    @property
    def depth(self) -> int:
        """The first group's stage-buffer depth (the whole megakernel's
        depth for a fused plan)."""
        return self.depths[0] if self.depths else 2

    @property
    def fused(self) -> bool:
        return len(self.groups) == 1

    @property
    def traffic_ratio(self) -> float:
        """Unfused / fused HBM words (>= 1: the fusion win)."""
        return self.unfused_traffic_words / max(self.traffic_words, 1)

    def to_json(self) -> Dict:
        return {
            "block": int(self.block),
            "groups": [list(g) for g in self.groups],
            "group_blocks": [int(b) for b in self.group_blocks],
            "depths": [int(d) for d in self.depths],
            "traffic_words": int(self.traffic_words),
            "unfused_traffic_words": int(self.unfused_traffic_words),
            "vmem_bytes": int(self.vmem_bytes),
            "modeled_seconds": float(self.modeled_seconds),
            "explored": int(self.explored),
            "pruned": int(self.pruned),
            "measured": bool(self.measured),
            "measured_seconds": float(self.measured_seconds),
            "timed": int(self.timed),
            "key": str(self.key),
        }

    @classmethod
    def from_json(cls, d: Dict) -> "PipelinePlan":
        return cls(block=int(d["block"]),
                   groups=tuple(tuple(g) for g in d["groups"]),
                   group_blocks=tuple(int(b)
                                      for b in d.get("group_blocks", ())),
                   depths=tuple(int(x) for x in d.get("depths", ())),
                   traffic_words=int(d["traffic_words"]),
                   unfused_traffic_words=int(d["unfused_traffic_words"]),
                   vmem_bytes=int(d["vmem_bytes"]),
                   modeled_seconds=float(d["modeled_seconds"]),
                   explored=int(d.get("explored", 0)),
                   pruned=int(d.get("pruned", 0)),
                   measured=bool(d.get("measured", False)),
                   measured_seconds=float(d.get("measured_seconds", 0.0)),
                   timed=int(d.get("timed", 0)),
                   key=str(d.get("key", "")),
                   cached=True)


def pipeline_key(pipe, *, vmem_budget: int = VMEM_BYTES,
                 align: int = MXU, extra: Tuple = (),
                 device: Optional[str] = None,
                 profile_hash: Optional[str] = None) -> str:
    """Tuning-cache key over the pipeline's *topological DAG*
    signature: every stage's structural signature, access descriptors,
    input tensor shapes/dtypes -- hashed in canonical topological order
    -- plus the wiring edges, the output set, the exploration
    constraints, the device kind and the calibration-profile hash.
    Any stage or wiring change invalidates the cached joint plan;
    reordering the declaration of independent stages does not (the DAG
    is the same program)."""
    from . import pipeline as plmod  # local import: keep layering thin

    device, profile_hash = _key_context(device, profile_hash)
    parts = []
    for s in plmod.topo_stages(pipe):
        inputs = tuple((t.name, tuple(t.shape), t.dtype)
                       for t in ir.inputs_of(s))
        # ir.signature omits a Map's elem_shape; the stage output shape
        # is part of the wiring, so hash it explicitly
        parts.append((s.name, ir.signature(s), _reads_sig(s), inputs,
                      s.dtype, tuple(s.shape)))
    edges = tuple(sorted(set(plmod._edges(pipe))))
    raw = repr((MODEL_VERSION, device, profile_hash, pipe.name,
                tuple(parts), edges,
                tuple(plmod.output_names(pipe)),
                int(vmem_budget), int(align), tuple(extra)))
    return hashlib.sha256(raw.encode()).hexdigest()[:32]


def _pipeline_candidates(pipe, align: int, max_points: int) -> List[int]:
    from . import pipeline as plmod  # local import: keep layering thin

    sub = max(dtype_sublane(s.dtype) for s in plmod.topo_stages(pipe))
    cands = axis_candidates(pipe.shared_extent, align, sublane=sub)
    while len(cands) > max_points and len(cands) > 2:
        cands = (cands[::2] if cands[-1] == cands[::2][-1]
                 else cands[::2] + [cands[-1]])
    return cands


def _price_pipeline_group(sub_pipe, b: int, *, vmem_budget: int,
                          profile, counters: Dict[str, int],
                          depth: int = 2):
    """Price the sub-pipeline fused at tile ``b`` with stage-buffer
    ``depth``: returns ``(hbm_words, vmem_bytes, analytic_s,
    calibrated_s, steps)`` or None when it busts VMEM / cannot fuse."""
    from . import pipeline as plmod  # local import: keep layering thin

    budget_words = max(vmem_budget // 4, 1)
    try:
        fdag = plmod.fuse_dag(sub_pipe, b, vmem_budget_words=budget_words)
    except (ValueError, NotImplementedError):
        return None
    counters["explored"] += 1
    mem = plan_memory(fdag.patterns, vmem_budget_bytes=vmem_budget,
                      depth=depth)
    if not mem.fits:
        counters["pruned"] += 1
        return None
    for t in fdag.patterns:   # streaming fallback left in place
        for q in ir.walk(t):
            for a in q.accesses:
                if isinstance(a.src, ir.Tensor) and a.affine:
                    counters["pruned"] += 1
                    return None
    reads = sum(plmod.dag_external_reads(fdag).values())
    out_w = plmod.output_words(sub_pipe)
    seconds = stream_seconds(reads + out_w)
    # time ratio: most conservative terminal schedule of the kernel
    # (pipe/seq < 1 is overlap speedup, > 1 exposed-latency slowdown)
    ratios = []
    for t in fdag.patterns:
        mp = build_schedule(t, budget_words, depth=depth)
        if mp is not None:
            body_words = sum(s.words for s in mp.stages
                             if s.kind in ("body", "compute"))
            seq, pipe, _ = model_speedup(
                mp, flops_per_body=body_words * 100.0)
            if seq > 0 and pipe > 0:
                ratios.append(pipe / seq)
    if ratios:
        seconds *= max(ratios)
    steps = int(fdag.grid)
    calibrated = calibrate.predicted_seconds(
        "Pipeline", seconds * HBM_BYTES_PER_S, steps, profile=profile)
    return (reads + out_w, mem.total_bytes, seconds, calibrated, steps)


@dataclasses.dataclass(frozen=True)
class PipelineTiming:
    """One shortlisted fused-pipeline candidate, lowered + timed."""

    block: int
    traffic_words: int
    vmem_bytes: int
    analytic_seconds: float
    calibrated_seconds: float
    steps: int
    measurement: measure_mod.Measurement
    plan: "PipelinePlan"
    depth: int = 2               # stage-buffer depth of the megakernel


def _time_pipeline_candidates(pipe, priced: List[Tuple], *,
                              vmem_budget: int, align: int,
                              timing_db, warmup: int, repeat: int,
                              policy: Optional[resilience.Policy] = None,
                              cache: Optional[TuningCache] = None
                              ) -> List[PipelineTiming]:
    """Lower + time whole fused-pipeline candidates (each a fully fused
    single-group ``PipelinePlan`` at one (block, depth) point).  Unlike
    the single-pattern path, depth IS part of the timing key: the
    megakernel's rotating stage scratch is allocated depth-deep, so
    depth variants are genuinely different executables.  Same failure
    discipline as ``_time_candidates``: deadline + retry + quarantine,
    never a crash of the exploration."""
    from . import pipeline as plmod
    from .codegen_pallas import lower_pipeline_for_timing

    n_stages = len(plmod.topo_stages(pipe))
    unfused = plmod.unfused_traffic_words(pipe)
    pol = resilience.resolve_policy(policy)
    out: List[PipelineTiming] = []
    for (b, d), (words, vmem, s_ana, s_cal, steps) in priced:
        variant = PipelinePlan(
            block=int(b), groups=((0, n_stages),),
            group_blocks=(int(b),), depths=(int(d),),
            traffic_words=int(words),
            unfused_traffic_words=unfused, vmem_bytes=int(vmem),
            modeled_seconds=float(s_cal))
        key = pipeline_key(pipe, vmem_budget=vmem_budget, align=align,
                           extra=("timing", int(b), int(d)),
                           device="", profile_hash="")
        qkey = "time|" + measure_mod.TimingDB.full_key(key)
        if cache is not None:
            q = cache.quarantined(qkey)
            if q is not None:
                resilience.record_once(
                    "time", q.get("kind", "unknown"), qkey, "skipped",
                    "previously quarantined candidate not re-attempted")
                continue

        def make_fn(variant=variant):
            return lower_pipeline_for_timing(pipe, variant,
                                             vmem_budget=vmem_budget)

        try:
            m = resilience.call_guarded(
                lambda: measure_mod.timed(key, make_fn, db=timing_db,
                                          warmup=warmup, repeat=repeat),
                stage="time", key=qkey, policy=pol)
        except resilience.CandidateFailure as e:
            resilience.record("time", e.kind, qkey, "quarantined",
                              e.detail)
            if cache is not None:
                cache.quarantine(qkey, e.kind, e.detail)
            continue
        out.append(PipelineTiming(
            block=int(b), traffic_words=int(words), vmem_bytes=int(vmem),
            analytic_seconds=s_ana, calibrated_seconds=s_cal,
            steps=steps, measurement=m, plan=variant, depth=int(d)))
    return out


def _observe_pipeline(pipe, timings: List[PipelineTiming]) -> None:
    samples = [calibrate.Sample(
        workload=f"Pipeline:{pipe.name}:{pipe.shared_extent}",
        kind="Pipeline",
        stream_bytes=t.analytic_seconds * HBM_BYTES_PER_S,
        steps=t.steps, measured_s=t.measurement.median_s,
        key=f"Pipeline:{pipe.name}:{pipe.shared_extent}"
            f"|b={t.block}d{t.depth}")
        for t in timings]
    if samples:
        calibrate.observe(samples)
    _accuracy_gauges("Pipeline", [(t.calibrated_seconds,
                                   t.measurement.median_s)
                                  for t in timings])


def _price_whole_pipeline(pipe, *, vmem_budget: int, align: int,
                          max_points: int, profile,
                          counters: Dict[str, int],
                          depths: Tuple[int, ...] = DEPTHS) -> List[Tuple]:
    """Every feasible fully fused (block, depth) candidate, priced and
    sorted best-first (the analytic shortlist of the whole DAG).
    Entries are ``((block, depth), (words, vmem, s_ana, s_cal, steps))``;
    ties in calibrated seconds break toward the shallowest depth."""
    from . import pipeline as plmod

    n_stages = len(plmod.topo_stages(pipe))
    try:
        whole = plmod.sub_pipeline(pipe, 0, n_stages)
    except (ValueError, NotImplementedError):
        return []
    priced = []
    for b in _pipeline_candidates(pipe, align, max_points):
        for d in depths:
            res = _price_pipeline_group(whole, b, vmem_budget=vmem_budget,
                                        profile=profile, counters=counters,
                                        depth=d)
            if res is not None:
                priced.append(((b, d), res))
    priced.sort(key=lambda t: (t[1][0], t[1][3], t[0][1], -t[1][1]))
    return priced


def measured_pipeline_shortlist(pipe, *,
                                top_k: int = TOP_K,
                                vmem_budget: int = VMEM_BYTES,
                                align: int = MXU,
                                max_points: int = MAX_POINTS,
                                profile=None,
                                timing_db=None,
                                warmup: int = MEASURE_WARMUP,
                                repeat: int = MEASURE_REPEAT,
                                calibrate_update: bool = True,
                                priced: Optional[List[Tuple]] = None,
                                depths: Tuple[int, ...] = DEPTHS,
                                policy: Optional[resilience.Policy]
                                = None,
                                cache: Union[None, bool, str,
                                             TuningCache] = False
                                ) -> List[PipelineTiming]:
    """Hybrid step for a pipeline DAG: analytically shortlist fully
    fused (block, depth) candidates, lower the top-k whole megakernels
    (depth-deep rotating stage scratch included), time them, optionally
    fold the samples into the calibration profile.  ``priced`` reuses
    an already-computed shortlist (``explore_pipeline`` passes its DP's
    whole-range pricing) instead of re-pricing.  ``policy``/``cache``
    mirror ``measured_shortlist``: deadline + retry per candidate,
    persistent quarantine when a cache is given."""
    if priced is None:
        priced = _price_whole_pipeline(
            pipe, vmem_budget=vmem_budget, align=align,
            max_points=max_points, profile=_resolve_profile(profile),
            counters={"explored": 0, "pruned": 0}, depths=depths)
    timings = _time_pipeline_candidates(
        pipe, priced[:max(top_k, 1)], vmem_budget=vmem_budget,
        align=align, timing_db=timing_db, warmup=warmup, repeat=repeat,
        policy=policy, cache=_resolve_cache(cache))
    if calibrate_update:
        _observe_pipeline(pipe, timings)
    return timings


def explore_pipeline(pipe, *,
                     vmem_budget: Optional[int] = None,
                     align: Optional[int] = None,
                     cache: Union[None, bool, str, TuningCache] = None,
                     max_points: Optional[int] = None,
                     measure: Optional[str] = None,
                     top_k: Optional[int] = None,
                     timing_db=None,
                     profile=None,
                     warmup: Optional[int] = None,
                     repeat: Optional[int] = None,
                     depths: Optional[Tuple[int, ...]] = None,
                     policy: Optional[resilience.Policy] = None,
                     bucketing: Optional[bool] = None,
                     options: Optional[Options] = None
                     ) -> PipelinePlan:
    """Joint design-space exploration for a pattern pipeline DAG.

    One tile candidate set is enumerated for the shared streaming
    domain (dtype-aware sublane alignment, ragged divisors) and crossed
    with the metapipeline buffer depths in ``depths`` (default
    ``DEPTHS = (2, 3, 4)``); each (block, depth) candidate prices the
    *fused* megakernel across the whole terminal set -- external
    traffic (fan-out tiles and stages charged once) plus metapipeline
    overlap and the DMA issue latency left exposed at that depth, with
    ``depth x`` VMEM charged per stage buffer and inter-stage traffic
    = 0 because intermediates live in the VMEM plan.  Ties in modeled
    seconds break toward the shallowest depth.  When no fused candidate
    fits VMEM the DAG is split into contiguous topological groups at
    the cheapest cuts, each group free to pick its *own* block size and
    depth (the split paths need not agree); every cut intermediate
    round-trips HBM.  The chosen per-group depths land in
    ``PipelinePlan.depths``.  Results are cached keyed on the
    topological DAG signature (+ device kind + calibration-profile
    hash + the resolved depth set).

    ``measure="top_k"`` (or ``REPRO_MEASURE=top_k``): when the analytic
    winner is fully fused, the top-k (block, depth) candidates are
    lowered as whole megakernels -- rotating depth-deep stage scratch
    included -- and timed; the measured argmin wins and the samples
    update the device calibration profile before the plan is cached.
    A split-fallback winner keeps the analytic choice (its groups
    execute as separate kernels; timing them jointly would conflate
    the cut traffic with tile effects).

    Measured candidates run under ``policy`` (deadline, transient
    retry), failures are quarantined in the tuning cache, and the
    measured winner must *certify* against the unfused per-stage
    oracle (``pipeline.run_unfused``) before promotion; when no
    candidate survives, the analytic plan ships and a fallback event
    is recorded -- candidate-level failures never raise.

    As in ``explore``, options may arrive packed in
    ``options=Options(...)`` (explicit kwarg > options > env > default)
    and ``bucketing=True`` enables bucketed warm starts: a cold
    ``shared_extent`` whose pipeline family has a tuned fused bucket is
    served an adapted plan immediately while a background re-tune
    promotes the certified exact-shape winner.
    """
    o = _resolve_options(options, vmem_budget=vmem_budget, align=align,
                         cache=cache, max_points=max_points,
                         measure=measure, top_k=top_k,
                         timing_db=timing_db, profile=profile,
                         warmup=warmup, repeat=repeat, depths=depths,
                         policy=policy, bucketing=bucketing)
    if o.trace:
        telemetry.enable()
    with telemetry.span("dse.explore_pipeline", pipeline=pipe.name) as sp:
        return _explore_pipeline_body(pipe, o, sp)


def _explore_pipeline_body(pipe, o: Options, sp) -> PipelinePlan:
    from . import pipeline as plmod  # local import: keep layering thin

    vmem_budget, align = o.vmem_budget, o.align
    max_points, measure, top_k = o.max_points, o.measure, o.top_k
    timing_db, profile = o.timing_db, o.profile
    warmup, repeat, depths, policy = (o.warmup, o.repeat, o.depths,
                                      o.policy)
    prof = _resolve_profile(profile)
    tc = _resolve_cache(o.cache)
    topo = plmod.topo_stages(pipe)
    n_stages = len(topo)
    cands = _pipeline_candidates(pipe, align, max_points)

    extra: Tuple = (tuple(cands),
                    ("depths",) + tuple(int(d) for d in depths))
    if measure:
        extra += (("measure", measure, int(top_k)),)

    def key_now() -> str:
        return pipeline_key(pipe, vmem_budget=vmem_budget, align=align,
                            extra=extra)

    bucketing_on = o.bucketing and tc is not None
    if bucketing_on:
        from . import buckets as buckets_mod

    if tc is not None:
        hit = tc.get(key_now(), PipelinePlan)
        if hit is not None:
            if bucketing_on:
                buckets_mod.note("exact_hits")
            telemetry.count("dse.cache_hits")
            hit = dataclasses.replace(hit, key=key_now())
            sp.set(source="cache")
            _record_plan(hit, source="cache")
            return hit

    if bucketing_on:
        warm = buckets_mod.warm_start_pipeline(
            pipe, tc, vmem_budget=vmem_budget, align=align,
            max_points=max_points)
        if warm is not None:
            buckets_mod.note("warm_hits")
            pol = resilience.resolve_policy(policy)
            # cache=False: the re-tune must not write the cache itself
            # -- only its *certified* winner is promoted, below
            retune_opts = dataclasses.replace(o, bucketing=False,
                                              cache=False)
            tag = "pipe|" + key_now()

            def _retune() -> PipelinePlan:
                return explore_pipeline(pipe, options=retune_opts)

            def _certify(plan: PipelinePlan):
                return resilience.certify_guarded(
                    lambda: resilience.certify_pipeline_plan(
                        pipe, plan, vmem_budget=vmem_budget),
                    key="retune|" + tag, policy=pol)

            def _promote(plan: PipelinePlan) -> None:
                # key recomputed at promotion time: the background
                # explore may have refreshed the calibration profile
                tc.put(key_now(), plan)
                buckets_mod.record_pipeline(pipe, plan, tc,
                                            vmem_budget=vmem_budget,
                                            align=align)

            buckets_mod.schedule_retune(tag, _retune, certify=_certify,
                                        promote=_promote, policy=pol)
            warm = dataclasses.replace(warm, key=key_now())
            sp.set(source="warm_start", bucket=warm.bucket)
            _record_plan(warm, source="warm_start", bucket=warm.bucket,
                         retune_tag=tag)
            return warm
        buckets_mod.note("misses")

    counters = {"explored": 0, "pruned": 0}

    # the fully fused (whole-range) candidates are priced once and
    # shared: they seed the DP's (0, n) entry AND the measured
    # shortlist below (no duplicate fuse_dag/plan_memory work)
    with telemetry.span("dse.shortlist", pipeline=pipe.name) as ssp:
        priced_whole = _price_whole_pipeline(
            pipe, vmem_budget=vmem_budget, align=align,
            max_points=max_points, profile=prof, counters=counters,
            depths=depths)
        ssp.set(fused_candidates=len(priced_whole))

    def best_group(i0: int, i1: int, memo: Dict):
        """Per-group (block, depth) choice: cheapest (words, seconds,
        vmem, block, depth) for topo stages [i0, i1) over the candidate
        tiles crossed with the buffer depths (shallowest wins ties)."""
        if (i0, i1) in memo:
            return memo[(i0, i1)]
        best = None
        try:
            # built once per range: block-independent (validate / topo
            # analysis is not free, cands can be large)
            sub_pipe = plmod.sub_pipeline(pipe, i0, i1)
        except (ValueError, NotImplementedError):
            # e.g. a cut that makes a terminal both output and
            # consumed: this grouping is simply infeasible
            sub_pipe = None
        if sub_pipe is not None:
            for b in cands:
                for d in depths:
                    priced = _price_pipeline_group(
                        sub_pipe, b, vmem_budget=vmem_budget,
                        profile=prof, counters=counters, depth=d)
                    if priced is None:
                        continue
                    rank = (priced[0], priced[3], d, -priced[1])
                    if best is None or rank < (best[0], best[1],
                                               best[4], -best[2]):
                        best = (priced[0], priced[3], priced[1], b, d)
        memo[(i0, i1)] = best
        return best

    # prefix DP over contiguous topological groups; fewer groups
    # preferred on ties (the j == 0 single-group candidate is tried
    # first and later candidates must be strictly cheaper)
    memo: Dict = {}
    if priced_whole:
        (b, d), (words, vmem, _, s_cal, _) = priced_whole[0]
        memo[(0, n_stages)] = (words, s_cal, vmem, b, d)
    else:
        memo[(0, n_stages)] = None
    state: List = [None] * (n_stages + 1)
    # words, seconds, vmem, groups, blocks, depths
    state[0] = (0, 0.0, 0, (), (), ())
    for i in range(1, n_stages + 1):
        for j in range(0, i):
            if state[j] is None:
                continue
            g = best_group(j, i, memo)
            if g is None:
                continue
            cand = (state[j][0] + g[0], state[j][1] + g[1],
                    max(state[j][2], g[2]),
                    state[j][3] + ((j, i),), state[j][4] + (g[3],),
                    state[j][5] + (g[4],))
            if state[i] is None or (cand[0], cand[1]) \
                    < (state[i][0], state[i][1]):
                state[i] = cand
    best = state[n_stages]
    if best is None:
        raise ValueError(
            "pipeline DSE: no tile candidate fits VMEM budget "
            f"{vmem_budget} B for '{pipe.name}' "
            f"({counters['explored']} candidates over {cands})")

    plan = PipelinePlan(
        block=int(best[4][0]), groups=best[3], group_blocks=best[4],
        traffic_words=int(best[0]),
        unfused_traffic_words=plmod.unfused_traffic_words(pipe),
        vmem_bytes=int(best[2]), modeled_seconds=float(best[1]),
        explored=counters["explored"], pruned=counters["pruned"],
        depths=best[5])

    prov_measured: List[Dict] = []
    prov_cert: List[Dict] = []
    if measure == "top_k" and plan.fused:
        pol = resilience.resolve_policy(policy)
        with telemetry.span("dse.measure", top_k=int(top_k)) as msp:
            # the resolved profile (prof=None means "uncalibrated",
            # whether from an explicit False or from no profile on
            # disk) must not re-resolve back to the on-disk profile
            # downstream
            timings = measured_pipeline_shortlist(
                pipe, top_k=top_k, vmem_budget=vmem_budget, align=align,
                max_points=max_points,
                profile=prof if prof is not None else False,
                timing_db=timing_db, warmup=warmup, repeat=repeat,
                priced=priced_whole, depths=depths, policy=pol,
                cache=tc if tc is not None else False)
            ranked = sorted(timings,
                            key=lambda t: (t.measurement.median_s,
                                           t.traffic_words, t.depth,
                                           -t.vmem_bytes))
            prov_measured = [
                {"block": int(t.block), "depth": int(t.depth),
                 "median_s": float(t.measurement.median_s)}
                for t in ranked]
            msp.set(timed=len(timings))
            promoted = False
            for win in ranked:
                if pol.certify:
                    ckey = "certify|" + measure_mod.TimingDB.full_key(
                        pipeline_key(pipe, vmem_budget=vmem_budget,
                                     align=align,
                                     extra=("certify", win.block,
                                            win.depth),
                                     device="", profile_hash=""))
                    if tc is not None \
                            and tc.quarantined(ckey) is not None:
                        # failed certification in a past run
                        prov_cert.append({"block": int(win.block),
                                          "depth": int(win.depth),
                                          "ok": False,
                                          "reason": "quarantined"})
                        continue
                    ok, reason = resilience.certify_guarded(
                        lambda w=win: resilience.certify_pipeline_plan(
                            pipe, w.plan, vmem_budget=vmem_budget),
                        key=ckey, policy=pol)
                    prov_cert.append({"block": int(win.block),
                                      "depth": int(win.depth),
                                      "ok": bool(ok), "reason": reason})
                    if not ok:
                        resilience.record("certify", "certify-failed",
                                          ckey, "quarantined", reason)
                        if tc is not None:
                            tc.quarantine(ckey, "certify-failed", reason)
                        continue
                plan = dataclasses.replace(
                    win.plan,
                    unfused_traffic_words=plan.unfused_traffic_words,
                    explored=counters["explored"],
                    pruned=counters["pruned"],
                    measured=True,
                    measured_seconds=win.measurement.median_s,
                    timed=len(timings))
                promoted = True
                break
            if not promoted:
                resilience.record(
                    "explore", "no-measured-winner",
                    f"Pipeline:{pipe.name}:{pipe.shared_extent}",
                    "fallback",
                    f"{len(timings)} timed, 0 certified; analytic plan "
                    "promoted instead")

    # key recomputed AFTER any calibration update: the next call
    # prices under the new profile hash and must hit this entry
    final_key = key_now()
    plan = dataclasses.replace(plan, key=final_key)
    if tc is not None:
        tc.put(final_key, plan)
        if bucketing_on:
            buckets_mod.record_pipeline(pipe, plan, tc,
                                        vmem_budget=vmem_budget,
                                        align=align)
    sp.set(source="explored", explored=plan.explored,
           pruned=plan.pruned, groups=len(plan.groups),
           timed=plan.timed)
    _record_plan(
        plan, source="explored",
        enumerated=plan.explored,
        pruned={"vmem": plan.pruned,
                "dominated": max(len(priced_whole) - plan.timed, 0)
                if plan.timed else 0},
        analytic_ranks=[
            {"block": int(b), "depth": int(d),
             "traffic_words": int(words),
             "calibrated_seconds": float(s_cal)}
            for (b, d), (words, _v, _sa, s_cal, _st)
            in priced_whole[:max(int(top_k), 3)]],
        measured_ranks=prov_measured,
        certification=prov_cert)
    return plan


# --------------------------------------------------------------------------
# Plan provenance: dse.explain
# --------------------------------------------------------------------------


def explain_dict(plan) -> Dict:
    """Machine-readable provenance report for a ``TilePlan`` /
    ``PipelinePlan``: where the winner came from (fresh exploration,
    tuning-cache hit, bucket warm start), what was enumerated and why
    candidates were rejected, the analytic and measured rankings and
    the certification outcomes.

    The deep exploration internals (rank tables, certification
    outcomes, per-reason pruning counts) are captured only while
    tracing is enabled (``REPRO_TRACE=1`` / ``Options(trace=True)``)
    and the plan was explored in this process; otherwise the report
    falls back to the accounting every plan carries on itself
    (explored/pruned totals, measured seconds, warm-start donor).
    """
    source = ("warm_start" if plan.warm_start
              else "cache" if plan.cached else "explored")
    d: Dict = {
        "kind": type(plan).__name__,
        "key": plan.key,
        "source": source,
        "explored": int(plan.explored),
        "pruned": int(plan.pruned),
        "traffic_words": int(plan.traffic_words),
        "vmem_bytes": int(plan.vmem_bytes),
        "modeled_seconds": float(plan.modeled_seconds),
        "measured": bool(plan.measured),
        "measured_seconds": float(plan.measured_seconds),
        "timed": int(plan.timed),
        "warm_start": bool(plan.warm_start),
        "bucket": plan.bucket,
        "cached": bool(plan.cached),
    }
    if isinstance(plan, PipelinePlan):
        d["block"] = int(plan.block)
        d["groups"] = [list(g) for g in plan.groups]
        d["depths"] = [int(x) for x in plan.depths]
    else:
        d["sizes"] = {k: list(v) for k, v in plan.sizes.items()}
        d["depths"] = {k: int(v) for k, v in plan.depths.items()}
        d["thinned"] = bool(plan.thinned)
    rec = telemetry.get_record("plan", plan.key) if plan.key else None
    if rec is not None:
        d["provenance"] = rec
        # the plan object's own warm_start flag is authoritative: the
        # background re-tune records its exploration under the same
        # key, but THIS plan is still the warm loan it was served as
        d["source"] = ("warm_start" if plan.warm_start
                       else rec.get("source", source))
    return d


def explain(plan) -> str:
    """Human-readable plan-provenance report (``explain_dict`` as
    text): winner source, tile/group choice, analytic vs measured
    ranks, per-reason pruning counts, certification outcomes."""
    d = explain_dict(plan)
    lines = [f"{d['kind']} {d['key'] or '<no key>'}",
             f"  source: {d['source']}"
             + (f" (bucket {d['bucket']})" if d["bucket"] else "")]
    if "sizes" in d:
        lines.append("  sizes: " + ", ".join(
            f"{k}={tuple(v)}" for k, v in sorted(d["sizes"].items())))
    else:
        lines.append(f"  block: {d['block']}  groups: {d['groups']}")
    lines.append(f"  depths: {d['depths']}")
    lines.append(f"  traffic: {d['traffic_words']} words   "
                 f"vmem: {d['vmem_bytes']} B   "
                 f"modeled: {d['modeled_seconds']:.3e} s")
    if d["measured"]:
        lines.append(f"  measured: {d['measured_seconds']:.3e} s "
                     f"({d['timed']} candidates timed)")
    lines.append(f"  enumerated: {d['explored']}  pruned: {d['pruned']}")
    rec = d.get("provenance")
    if rec:
        pr = rec.get("pruned")
        if isinstance(pr, dict):
            lines.append("  pruned by reason: " + ", ".join(
                f"{k}={v}" for k, v in sorted(pr.items())))
        for label, keyname in (("analytic ranks", "analytic_ranks"),
                               ("measured ranks", "measured_ranks")):
            rows = rec.get(keyname)
            if rows:
                lines.append(f"  {label}:")
                lines.extend(
                    f"    {i + 1}. " + ", ".join(f"{k}={v}"
                                                 for k, v in r.items())
                    for i, r in enumerate(rows))
        for c in rec.get("certification") or ():
            ident = ", ".join(f"{k}={v}" for k, v in c.items()
                              if k not in ("ok", "reason"))
            verdict = ("certified" if c.get("ok")
                       else f"FAILED ({c.get('reason', '')})")
            lines.append(f"  certify {ident}: {verdict}")
    else:
        lines.append("  (no in-process trace record; run with "
                     "REPRO_TRACE=1 for rank tables and pruning "
                     "reasons)")
    return "\n".join(lines)


# --------------------------------------------------------------------------
# Proxy programs: PPL models of the Pallas kernels' loop structure.
# Bodies are only analyzed (traffic / memory / schedule), never executed,
# but are kept runnable for the codegen_jax oracle where cheap to do so.
# --------------------------------------------------------------------------


def attention_program(sq: int, sk: int, d: int) -> ir.Pattern:
    """Flash attention as Map(queries){ MultiFold(keys) } -- the online-
    softmax fold over keys nested in the query map (DESIGN.md §4).

    Tileable domains: ``fa_q`` (query block) and ``fa_kv`` (kv block).
    """
    import jax.numpy as jnp

    q = ir.Tensor("q", (sq, d))
    k = ir.Tensor("k", (sk, d))
    v = ir.Tensor("v", (sk, d))
    kv = ir.MultiFold(
        domain=(sk,), range_shape=(d,),
        init=lambda: jnp.zeros((d,)),
        reads=(ir.Access(q, lambda i, kk: (i, 0), (1, d)),
               ir.Access(k, lambda i, kk: (kk, 0), (1, d)),
               ir.Access(v, lambda i, kk: (kk, 0), (1, d))),
        out_index_map=lambda i, kk: (0,), update_shape=(d,),
        fn=lambda s, acc, qe, ke, ve: acc + jnp.sum(qe * ke) * ve,
        combine=lambda a, b: a + b, name="fa_kv")
    return ir.Map(domain=(sq,), elem_shape=(d,), inner=kv, name="fa_q")


def scan_program(seq: int, n: int, dh: int) -> ir.Pattern:
    """The SSD chunked scan's sequence fold: per step read an x row, a
    dt scalar and B/C rows, update the carried (n, dh) state.

    Tileable domain: ``ssd`` (the chunk length).
    """
    import jax.numpy as jnp

    x = ir.Tensor("x", (seq, dh))
    dt = ir.Tensor("dt", (seq,))
    B = ir.Tensor("B", (seq, n))
    C = ir.Tensor("C", (seq, n))
    return ir.MultiFold(
        domain=(seq,), range_shape=(n, dh),
        init=lambda: jnp.zeros((n, dh)),
        reads=(ir.Access(x, lambda i: (i, 0), (1, dh)),
               ir.elem(dt),
               ir.Access(B, lambda i: (i, 0), (1, n)),
               ir.Access(C, lambda i: (i, 0), (1, n))),
        out_index_map=lambda i: (0, 0), update_shape=(n, dh),
        fn=lambda s, acc, xe, dte, be, ce: acc + jnp.outer(be, xe) * dte,
        combine=lambda a, b: a + b, name="ssd")


def filter_reduce_program(t: int) -> ir.Pattern:
    """TPC-H Q6 shape: fused filter + weighted-sum fold over one stream
    (tileable domain: ``fr``)."""
    import jax.numpy as jnp

    x = ir.Tensor("x", (t,))
    w = ir.Tensor("w", (t,))
    return ir.MultiFold(
        domain=(t,), range_shape=(), init=lambda: jnp.zeros(()),
        reads=(ir.elem(x), ir.elem(w)),
        out_index_map=lambda i: (), update_shape=(),
        fn=lambda s, acc, xe, we: acc + xe * we,
        combine=lambda a, b: a + b, name="fr")


def groupby_program(t: int, num_keys: int, ew: int) -> ir.Pattern:
    """Keyed fold over a (t,) stream into a dense (num_keys, ew)
    accumulator (tileable domain: ``gbf``)."""
    import jax.numpy as jnp

    keys = ir.Tensor("keys", (t,), "int32")
    vals = ir.Tensor("vals", (t, ew))
    return ir.GroupByFold(
        domain=(t,), num_keys=num_keys, elem_shape=(ew,),
        init=lambda: jnp.zeros((num_keys, ew)),
        reads=(ir.elem(keys),
               ir.Access(vals, lambda i: (i, 0), (1, ew))),
        fn=lambda s, ke, ve: (ke.astype("int32"), ve),
        combine=lambda a, b: a + b, name="gbf")


def gemm_program(m: int, n: int, k: int) -> ir.Pattern:
    """The Table-3 GEMM (from the benchmark suite builders)."""
    from repro.patterns.analytics import gemm
    p, _, _, _ = gemm(m, n, k)
    return p


# --------------------------------------------------------------------------
# Kernel-facing block-size selection (one entry point per Pallas kernel)
# --------------------------------------------------------------------------


def _one(plan: TilePlan, name: str) -> Tuple[int, ...]:
    return tuple(int(x) for x in plan.sizes[name])


def select_gemm_blocks(m: int, n: int, k: int, *,
                       vmem_budget: Optional[int] = None,
                       align: Optional[int] = None,
                       cache: Union[None, bool, str, TuningCache] = None,
                       measure: Optional[str] = None,
                       policy: Optional[resilience.Policy] = None,
                       options: Optional[Options] = None
                       ) -> Tuple[Tuple[int, int, int], TilePlan]:
    plan = explore(gemm_program(m, n, k), vmem_budget=vmem_budget,
                   align=align, cache=cache, measure=measure,
                   policy=policy, options=options)
    (bm, bn), (bk,) = _one(plan, "gemm"), _one(plan, "gemm_k")
    return (bm, bn, bk), plan


def select_attention_blocks(sq: int, sk: int, d: int, *,
                            vmem_budget: Optional[int] = None,
                            align: Optional[int] = None,
                            cache: Union[None, bool, str, TuningCache] = None,
                            measure: Optional[str] = None,
                            policy: Optional[resilience.Policy] = None,
                            options: Optional[Options] = None
                            ) -> Tuple[Tuple[int, int], TilePlan]:
    plan = explore(attention_program(sq, sk, d), vmem_budget=vmem_budget,
                   align=align, cache=cache, measure=measure,
                   policy=policy, options=options)
    (bq,), (bk,) = _one(plan, "fa_q"), _one(plan, "fa_kv")
    return (bq, bk), plan


def select_scan_blocks(seq: int, n: int, dh: int, *,
                       vmem_budget: Optional[int] = None,
                       align: Optional[int] = None,
                       cache: Union[None, bool, str, TuningCache] = None,
                       measure: Optional[str] = None,
                       policy: Optional[resilience.Policy] = None,
                       options: Optional[Options] = None
                       ) -> Tuple[int, TilePlan]:
    plan = explore(scan_program(seq, n, dh), vmem_budget=vmem_budget,
                   align=align, cache=cache, measure=measure,
                   policy=policy, options=options)
    (chunk,) = _one(plan, "ssd")
    return chunk, plan


def select_filter_reduce_blocks(t: int, *,
                                vmem_budget: Optional[int] = None,
                                align: Optional[int] = None,
                                cache: Union[None, bool, str,
                                             TuningCache] = None,
                                measure: Optional[str] = None,
                                policy: Optional[resilience.Policy]
                                = None,
                                options: Optional[Options] = None
                                ) -> Tuple[int, TilePlan]:
    plan = explore(filter_reduce_program(t), vmem_budget=vmem_budget,
                   align=align, cache=cache, measure=measure,
                   policy=policy, options=options)
    (bt,) = _one(plan, "fr")
    return bt, plan


def select_groupby_blocks(t: int, num_keys: int, ew: int, *,
                          vmem_budget: Optional[int] = None,
                          align: Optional[int] = None,
                          cache: Union[None, bool, str, TuningCache] = None,
                          measure: Optional[str] = None,
                          policy: Optional[resilience.Policy] = None,
                          options: Optional[Options] = None
                          ) -> Tuple[int, TilePlan]:
    plan = explore(groupby_program(t, num_keys, ew),
                   vmem_budget=vmem_budget, align=align, cache=cache,
                   measure=measure, policy=policy, options=options)
    (bt,) = _one(plan, "gbf")
    return bt, plan


def filter_fold_pipeline(t: int):
    """TPC-H Q6 as a two-stage *pipeline*: a mask Map producing the
    per-record contribution, folded by a separate sum stage.  The fused
    lowering keeps the (t,) intermediate in VMEM scratch; the unfused
    lowering round-trips it through HBM (the quantity
    ``PipelinePlan.traffic_ratio`` reports)."""
    import jax.numpy as jnp

    from .pipeline import Pipeline

    x = ir.Tensor("x", (t,))
    w = ir.Tensor("w", (t,))
    mask = ir.Map(domain=(t,), reads=(ir.elem(x), ir.elem(w)),
                  fn=lambda s, xe, we: xe * we, name="ff_mask")
    total = ir.MultiFold(
        domain=(t,), range_shape=(), init=lambda: jnp.zeros(()),
        reads=(ir.elem(ir.Tensor("ff_mask", (t,))),),
        out_index_map=lambda i: (), update_shape=(),
        fn=lambda s, acc, v: acc + v,
        combine=lambda a, b: a + b, name="ff_sum")
    return Pipeline(name="filter_fold", stages=(mask, total))


def select_fused_filter_fold_blocks(
        t: int, *, vmem_budget: Optional[int] = None,
        align: Optional[int] = None,
        cache: Union[None, bool, str, TuningCache] = None,
        measure: Optional[str] = None,
        policy: Optional[resilience.Policy] = None,
        options: Optional[Options] = None
        ) -> Tuple[int, PipelinePlan]:
    """Joint-DSE streaming tile for the fused filter+fold megakernel."""
    plan = explore_pipeline(filter_fold_pipeline(t),
                            vmem_budget=vmem_budget, align=align,
                            cache=cache, measure=measure, policy=policy,
                            options=options)
    return plan.block, plan


def select_fused_kmeans_blocks(
        n: int, k: int, d: int, *, vmem_budget: Optional[int] = None,
        align: Optional[int] = None,
        cache: Union[None, bool, str, TuningCache] = None,
        measure: Optional[str] = None,
        policy: Optional[resilience.Policy] = None,
        options: Optional[Options] = None
        ) -> Tuple[int, PipelinePlan]:
    """Joint-DSE streaming tile for the fused k-means DAG megakernel
    (assign -> {scatter-sum, count}; one plan for the whole DAG, cached
    on its topological signature)."""
    from repro.patterns.analytics import kmeans_pipeline
    pipe, _, _ = kmeans_pipeline(n, k, d)
    plan = explore_pipeline(pipe, vmem_budget=vmem_budget, align=align,
                            cache=cache, measure=measure, policy=policy,
                            options=options)
    return plan.block, plan


# --------------------------------------------------------------------------
# Paged serving decode: layout x page_size x block as joint DSE axes
# --------------------------------------------------------------------------

PAGED_LAYOUTS = ("split", "fused")   # split K/V pools vs head-interleaved
PAGE_SIZES = (8, 16, 32, 64)


def paged_decode_pipeline(max_len: int, page_size: int, d: int,
                          layout: str = "split"):
    """One decode step as the ``decode_attention`` pipeline DAG: a
    KV-append producer Map (merge the step's token at the ``seq_len``
    slot) feeding a flash-attention MultiFold terminal, over a *ragged*
    streaming domain (``ir.RaggedExtent``): the static extent is the
    page-padded context bound, the live extent the runtime ``seq_len``
    scalar, masked at page granularity.

    ``layout`` picks the KV stream shape the candidate prices:
    ``split`` streams separate K and V rows through two producer
    stages; ``fused`` streams one head-interleaved ``2d`` row through a
    single stage (half the streams, double the row width) -- same total
    words, different stream count / stage structure, which is exactly
    what the metapipeline model differentiates.
    """
    import jax.numpy as jnp

    from .pipeline import Pipeline

    if layout not in PAGED_LAYOUTS:
        raise ValueError(f"layout {layout!r}; one of {PAGED_LAYOUTS}")
    padded = -(-max_len // page_size) * page_size
    rag = ir.RaggedExtent(max=padded, length_name="seq_len",
                          granularity=page_size)
    q = ir.Tensor("q", (1, d))
    seq_len = ir.Tensor("seq_len", (1,), "int32")
    scale = d ** -0.5

    def append_fn(s, pagerow, new, ln):
        pagerow = jnp.reshape(pagerow, (-1,))
        new = jnp.reshape(new, (-1,))
        return jnp.where(s[0] == jnp.reshape(ln, ()), new, pagerow)

    if layout == "fused":
        pages = ir.Tensor("kv_pages", (padded, 2 * d))
        new_kv = ir.Tensor("new_kv", (1, 2 * d))
        append = ir.Map(
            domain=(padded,), elem_shape=(2 * d,),
            reads=(ir.Access(pages, lambda i: (i, 0), (1, 2 * d)),
                   ir.whole(new_kv), ir.whole(seq_len)),
            fn=append_fn, name="pd_append", ragged=rag)

        def fold_fn(s, acc, kvrow, qv, ln):
            kvrow = jnp.reshape(kvrow, (-1,))
            qv = jnp.reshape(qv, (-1,))
            w = jnp.where(s[0] <= jnp.reshape(ln, ()),
                          jnp.exp(jnp.sum(qv * kvrow[:d]) * scale), 0.0)
            return acc + w * kvrow[d:]

        fold = ir.MultiFold(
            domain=(padded,), range_shape=(d,),
            init=lambda: jnp.zeros((d,)),
            reads=(ir.Access(ir.Tensor("pd_append", (padded, 2 * d)),
                             lambda i: (i, 0), (1, 2 * d)),
                   ir.whole(q), ir.whole(seq_len)),
            out_index_map=lambda i: (0,), update_shape=(d,),
            fn=fold_fn, combine=lambda a, b: a + b, name="pd_kv",
            ragged=rag)
        return Pipeline(name="paged_decode_fused",
                        stages=(append, fold))

    k_pages = ir.Tensor("k_pages", (padded, d))
    v_pages = ir.Tensor("v_pages", (padded, d))
    new_k = ir.Tensor("new_k", (1, d))
    new_v = ir.Tensor("new_v", (1, d))
    app_k = ir.Map(
        domain=(padded,), elem_shape=(d,),
        reads=(ir.Access(k_pages, lambda i: (i, 0), (1, d)),
               ir.whole(new_k), ir.whole(seq_len)),
        fn=append_fn, name="pd_append_k", ragged=rag)
    app_v = ir.Map(
        domain=(padded,), elem_shape=(d,),
        reads=(ir.Access(v_pages, lambda i: (i, 0), (1, d)),
               ir.whole(new_v), ir.whole(seq_len)),
        fn=append_fn, name="pd_append_v", ragged=rag)

    def fold_fn_split(s, acc, krow, vrow, qv, ln):
        krow = jnp.reshape(krow, (-1,))
        vrow = jnp.reshape(vrow, (-1,))
        qv = jnp.reshape(qv, (-1,))
        w = jnp.where(s[0] <= jnp.reshape(ln, ()),
                      jnp.exp(jnp.sum(qv * krow) * scale), 0.0)
        return acc + w * vrow

    fold = ir.MultiFold(
        domain=(padded,), range_shape=(d,),
        init=lambda: jnp.zeros((d,)),
        reads=(ir.Access(ir.Tensor("pd_append_k", (padded, d)),
                         lambda i: (i, 0), (1, d)),
               ir.Access(ir.Tensor("pd_append_v", (padded, d)),
                         lambda i: (i, 0), (1, d)),
               ir.whole(q), ir.whole(seq_len)),
        out_index_map=lambda i: (0,), update_shape=(d,),
        fn=fold_fn_split, combine=lambda a, b: a + b, name="pd_kv",
        ragged=rag)
    return Pipeline(name="paged_decode_split",
                    stages=(app_k, app_v, fold))


def select_paged_decode_blocks(
        max_len: int, d: int, *, vmem_budget: Optional[int] = None,
        align: Optional[int] = None,
        cache: Union[None, bool, str, TuningCache] = None,
        measure: Optional[str] = None,
        policy: Optional[resilience.Policy] = None,
        options: Optional[Options] = None
        ) -> Tuple[Tuple[str, int, int, int], TilePlan]:
    """Joint search over KV layout x page size x streaming block x
    metapipeline depth for the fused paged-decode kernel.

    Every (layout, page_size) pair prices its own ``decode_attention``
    proxy DAG through ``explore_pipeline`` (block x depth inside, with
    the pipeline tuning cache and -- via ``options.bucketing`` -- the
    shape-bucket warm-start layer, bucketed on the padded max length);
    the argmin on modeled seconds wins.  Returns ``((layout,
    page_size, block, depth), plan)`` with ``plan`` a summary
    ``TilePlan`` whose provenance records the searched joint axes:
    ``sizes["pd_kv"]`` the streaming block, ``sizes["pd_page"]`` the
    page size, ``sizes["pd_layout"]`` the layout's ``PAGED_LAYOUTS``
    index, ``depths["pd_kv"]`` the buffer depth.
    """
    page_sizes = [p for p in PAGE_SIZES if p <= max(max_len, PAGE_SIZES[0])]
    best = None
    explored = pruned = timed = 0
    for layout in PAGED_LAYOUTS:
        for ps in page_sizes:
            pipe = paged_decode_pipeline(max_len, ps, d, layout)
            plan = explore_pipeline(pipe, vmem_budget=vmem_budget,
                                    align=align, cache=cache,
                                    measure=measure, policy=policy,
                                    options=options)
            explored += plan.explored
            pruned += plan.pruned
            timed += plan.timed
            if best is None or (plan.modeled_seconds
                                < best[2].modeled_seconds):
                best = (layout, ps, plan)
    layout, ps, pplan = best
    summary = TilePlan(
        sizes={"pd_kv": (int(pplan.block),), "pd_page": (int(ps),),
               "pd_layout": (PAGED_LAYOUTS.index(layout),)},
        traffic_words=pplan.traffic_words,
        vmem_bytes=pplan.vmem_bytes,
        modeled_seconds=pplan.modeled_seconds,
        explored=explored, pruned=pruned,
        cached=pplan.cached, measured=pplan.measured,
        measured_seconds=pplan.measured_seconds, timed=timed,
        depths={"pd_kv": int(pplan.depth)},
        warm_start=pplan.warm_start, bucket=pplan.bucket,
        key=pplan.key)
    return (layout, int(ps), int(pplan.block), int(pplan.depth)), summary
