"""Process-wide telemetry: tracing spans, a typed metrics registry,
structured event streams, and trace/metrics exporters.

One subsystem, four faces:

* **Spans** -- ``with span("dse.explore", pattern=p.name):`` records a
  wall-clock interval with nesting (per-thread stack) and attached
  attributes.  Spans are *gated*: they exist only when tracing is
  enabled (``REPRO_TRACE=1`` / ``Options(trace=True)``, resolved
  through ``Options.from_env`` like every other tuning option).  When
  disabled, ``span()`` returns a shared no-op singleton -- one global
  check, no allocation, no string formatting -- so instrumentation
  sites cost nothing in production.  Spans wrap host-side
  orchestration only; nothing here may run inside jitted/pallas code.
* **Metrics** -- ``count`` / ``gauge`` (always-on: they replace the
  ad-hoc stat dicts that used to live in ``buckets``/``serve``) and
  ``observe`` (latency histograms with fixed log-spaced bounds,
  deterministic across runs; gated like spans).
* **Events** -- ``emit(stream, kind, **fields)`` is the single
  structured event stream in the repo; ``resilience.EventLog`` and
  ``runtime.fault_tolerance.RecoveryLog`` are facades over it.
* **Exporters** -- ``export_trace(path)`` writes Chrome trace-event
  JSON (loadable at https://ui.perfetto.dev; background re-tune
  daemons land in their own thread lanes) and ``metrics_snapshot()``
  returns the flat dict ``benchmarks/run.py`` merges into the BENCH
  json.

``put_record`` / ``get_record`` is a small gated provenance store the
DSE uses to back ``dse.explain(plan)`` with the full exploration
record (enumerated / pruned-with-reason / ranks / certification).

Everything is thread-safe (one module lock around shared state;
per-thread span stacks are lock-free) and bounded (span/event buffers
cap out and count drops rather than growing without limit).
"""
from __future__ import annotations

import bisect
import json
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "enabled", "enable", "disable", "reset", "span", "count", "gauge",
    "observe", "emit", "events", "clear_events", "put_record",
    "get_record", "log_bounds", "LATENCY_BOUNDS_S", "export_trace",
    "metrics_snapshot", "span_log",
]

_LOCK = threading.RLock()
_TLS = threading.local()
_T0 = time.perf_counter()

MAX_SPANS = 200_000
MAX_EVENTS = 100_000
MAX_RECORDS = 1024

# None = not yet resolved; resolved lazily from Options.from_env() so
# plain REPRO_TRACE=1 runs trace without any code opting in.
_enabled: Optional[bool] = None

_spans: List[Dict[str, Any]] = []
_dropped_spans = 0
_counters: Dict[str, float] = {}
_gauges: Dict[str, float] = {}
_hists: Dict[str, Dict[str, Any]] = {}
_events: List[Dict[str, Any]] = []
_dropped_events = 0
_records: Dict[Tuple[str, str], Any] = {}


# ------------------------------------------------------------------
# enablement
# ------------------------------------------------------------------


def _resolve_enabled() -> bool:
    global _enabled
    from .options import Options  # local: keep module import-free

    _enabled = bool(Options.from_env().resolved().trace)
    return _enabled


def enabled() -> bool:
    """Is tracing on?  Lazily resolved from ``REPRO_TRACE`` (through
    ``Options.from_env``) on first call; ``enable()``/``disable()``
    override programmatically."""
    if _enabled is None:
        return _resolve_enabled()
    return _enabled


def enable() -> None:
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def reset() -> None:
    """Clear all recorded telemetry and re-arm env-based enablement."""
    global _enabled, _dropped_spans, _dropped_events
    with _LOCK:
        _enabled = None
        _spans.clear()
        _dropped_spans = 0
        _counters.clear()
        _gauges.clear()
        _hists.clear()
        _events.clear()
        _dropped_events = 0
        _records.clear()


# ------------------------------------------------------------------
# spans
# ------------------------------------------------------------------


class _NullSpan:
    """The disabled-mode singleton: every instrumentation site gets
    this same object back, so tracing-off costs one global check and
    zero allocations."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kv):
        return self


NULL_SPAN = _NullSpan()


def _stack() -> list:
    s = getattr(_TLS, "stack", None)
    if s is None:
        s = _TLS.stack = []
    return s


class Span:
    __slots__ = ("name", "args", "_ts")

    def __init__(self, name: str, args: Dict[str, Any]):
        self.name = name
        self.args = args
        self._ts = 0.0

    def set(self, **kv):
        """Attach attributes discovered mid-span (e.g. the winner)."""
        self.args.update(kv)
        return self

    def __enter__(self):
        self._ts = (time.perf_counter() - _T0) * 1e6
        _stack().append(self)
        return self

    def __exit__(self, exc_type, exc, tb):
        global _dropped_spans
        dur = (time.perf_counter() - _T0) * 1e6 - self._ts
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        th = threading.current_thread()
        ev: Dict[str, Any] = {
            "name": self.name, "ph": "X",
            "ts": self._ts, "dur": dur,
            "tid": th.ident, "thread": th.name,
        }
        if st:
            ev["parent"] = st[-1].name
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        if self.args:
            ev["args"] = self.args
        with _LOCK:
            if len(_spans) < MAX_SPANS:
                _spans.append(ev)
            else:
                _dropped_spans += 1
        return False


def span(name: str, **attrs):
    """A tracing span context manager.  Disabled -> shared no-op."""
    if not (_enabled if _enabled is not None else _resolve_enabled()):
        return NULL_SPAN
    return Span(name, attrs)


def span_log() -> List[Dict[str, Any]]:
    """Finished spans recorded so far (copies; test/export surface)."""
    with _LOCK:
        return list(_spans)


# ------------------------------------------------------------------
# metrics registry
# ------------------------------------------------------------------


def count(name: str, n: float = 1) -> None:
    """Increment a counter.  Always on: counters replace the ad-hoc
    stat dicts (``buckets.STATS`` etc.), so they must exist with or
    without tracing."""
    with _LOCK:
        _counters[name] = _counters.get(name, 0) + n


def gauge(name: str, value: float) -> None:
    """Set a gauge to its latest value (always on; model-accuracy
    gauges feed the regression gate without tracing enabled)."""
    with _LOCK:
        _gauges[name] = value


def log_bounds(lo: float, hi: float, per_decade: int = 4
               ) -> Tuple[float, ...]:
    """Deterministic log-spaced histogram bounds: ``per_decade`` edges
    per factor of 10 from ``lo`` up to (at least) ``hi``.  Pure
    arithmetic on the arguments -- the same call always returns the
    same tuple, so exported histograms are comparable across runs."""
    if lo <= 0 or hi <= lo or per_decade < 1:
        raise ValueError(f"log_bounds({lo}, {hi}, {per_decade})")
    out = []
    i = 0
    while True:
        edge = lo * 10.0 ** (i / per_decade)
        out.append(edge)
        if edge >= hi:
            break
        i += 1
    return tuple(out)


#: default latency bounds: 1 microsecond .. 100 s, 4 buckets/decade
LATENCY_BOUNDS_S = log_bounds(1e-6, 1e2, per_decade=4)


def observe(name: str, value: float,
            bounds: Tuple[float, ...] = LATENCY_BOUNDS_S) -> None:
    """Record ``value`` into histogram ``name``.  Gated: with tracing
    disabled this returns before touching (or creating) any registry
    entry, so instrumentation-only histograms add zero overhead and
    zero registry growth in production."""
    if not (_enabled if _enabled is not None else _resolve_enabled()):
        return
    with _LOCK:
        h = _hists.get(name)
        if h is None:
            h = _hists[name] = {"bounds": tuple(bounds),
                                "counts": [0] * (len(bounds) + 1),
                                "count": 0, "sum": 0.0}
        h["counts"][bisect.bisect_right(h["bounds"], value)] += 1
        h["count"] += 1
        h["sum"] += value


# ------------------------------------------------------------------
# structured event stream
# ------------------------------------------------------------------


def emit(stream: str, kind: str, **fields) -> Dict[str, Any]:
    """Append a structured event to the process-wide stream.  Always
    on (this is the single event sink behind ``resilience.EventLog``
    and ``runtime.fault_tolerance.RecoveryLog``)."""
    global _dropped_events
    ev = {"stream": stream, "kind": kind, "t": time.time(),
          "ts": (time.perf_counter() - _T0) * 1e6}
    ev.update(fields)
    with _LOCK:
        if len(_events) < MAX_EVENTS:
            _events.append(ev)
        else:
            _dropped_events += 1
    return ev


def events(stream: Optional[str] = None, **match) -> List[Dict[str, Any]]:
    """Recorded events, optionally filtered by stream and field values."""
    with _LOCK:
        evs = list(_events)
    if stream is not None:
        evs = [e for e in evs if e["stream"] == stream]
    for k, v in match.items():
        evs = [e for e in evs if e.get(k) == v]
    return evs


def clear_events(stream: Optional[str] = None) -> None:
    with _LOCK:
        if stream is None:
            _events.clear()
        else:
            _events[:] = [e for e in _events if e["stream"] != stream]


# ------------------------------------------------------------------
# provenance records (dse.explain backing store)
# ------------------------------------------------------------------


def put_record(kind: str, key: str, payload: Any) -> None:
    """Store a provenance record (bounded LRU).  Gated: provenance is
    recorded only while tracing, matching the spans it summarizes."""
    if not (_enabled if _enabled is not None else _resolve_enabled()):
        return
    with _LOCK:
        _records.pop((kind, key), None)
        _records[(kind, key)] = payload
        while len(_records) > MAX_RECORDS:
            _records.pop(next(iter(_records)))


def get_record(kind: str, key: str) -> Any:
    with _LOCK:
        return _records.get((kind, key))


# ------------------------------------------------------------------
# exporters
# ------------------------------------------------------------------


def export_trace(path: str) -> str:
    """Write everything recorded so far as Chrome trace-event JSON.

    Loadable by https://ui.perfetto.dev or ``chrome://tracing``: spans
    become complete ("X") events with microsecond ``ts``/``dur`` in
    per-thread lanes (thread_name metadata names each lane, so
    background ``repro-retune-*`` daemons are visible next to the main
    thread), structured events become instant ("i") marks.  Timed
    events are sorted by ``ts`` so consumers see monotone timestamps.
    """
    with _LOCK:
        spans = list(_spans)
        evs = list(_events)
    lanes: Dict[Any, int] = {}
    meta: List[Dict[str, Any]] = []

    def lane(raw_tid, name) -> int:
        if raw_tid not in lanes:
            lanes[raw_tid] = len(lanes) + 1
            meta.append({"name": "thread_name", "ph": "M", "pid": 1,
                         "tid": lanes[raw_tid], "ts": 0,
                         "args": {"name": str(name)}})
        return lanes[raw_tid]

    timed: List[Dict[str, Any]] = []
    for s in spans:
        ev = {"name": s["name"], "ph": "X", "pid": 1,
              "tid": lane(s.get("tid"), s.get("thread", "thread")),
              "ts": s["ts"], "dur": s["dur"]}
        args = dict(s.get("args") or {})
        if s.get("parent"):
            args["parent"] = s["parent"]
        if args:
            ev["args"] = {k: _jsonable(v) for k, v in args.items()}
        timed.append(ev)
    for e in evs:
        ev = {"name": f"{e['stream']}.{e['kind']}", "ph": "i",
              "pid": 1, "tid": lane(None, "events"), "ts": e["ts"],
              "s": "p",
              "args": {k: _jsonable(v) for k, v in e.items()
                       if k not in ("stream", "kind", "ts")}}
        timed.append(ev)
    timed.sort(key=lambda ev: ev["ts"])
    doc = {"traceEvents": meta + timed, "displayTimeUnit": "ms"}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    return path


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return str(v)


def metrics_snapshot() -> Dict[str, Any]:
    """Flat, JSON-able snapshot of the registry: counters, gauges,
    histogram tables, per-stream event counts, span accounting.  This
    is what ``benchmarks/run.py`` merges into the BENCH json."""
    with _LOCK:
        streams: Dict[str, int] = {}
        for e in _events:
            streams[e["stream"]] = streams.get(e["stream"], 0) + 1
        return {
            "counters": dict(_counters),
            "gauges": {k: _jsonable(v) for k, v in _gauges.items()},
            "histograms": {
                name: {"bounds": list(h["bounds"]),
                       "counts": list(h["counts"]),
                       "count": h["count"], "sum": h["sum"]}
                for name, h in _hists.items()},
            "events": streams,
            "spans": len(_spans),
            "dropped": {"spans": _dropped_spans,
                        "events": _dropped_events},
        }
