"""Pattern interchange: the two Collect-Reduce reordering rules (§4).

Both rules match the special case of MultiFold where every iteration
updates the entire accumulator (a *fold*) and move strided patterns out
of unstrided ones to increase tile reuse:

  Rule 1:  Map(d_m){ fold(d_f/b)(z)(body)(c) }
        -> fold(d_f/b)(bcast z){ Map(d_m){ body } }(lifted c)
     (a scalar strided fold moves out of an unstrided Map; the fold's
      combine becomes a Map -- realized here by requiring combines to be
      shape-polymorphic elementwise functions)

  Rule 2:  fold(d_f){ MultiFold_writeonce(d_m/b){ body } }
        -> MultiFold_writeonce(d_m/b){ fold(d_f){ body } }
     (the outer pattern of a tiled Map moves out of an unstrided fold)

Interchange runs between strip mining and tile-copy insertion, so
matched nodes carry no tile loads yet.  The index-stack segments of the
two patterns swap; every callable in the moved subtrees is re-wrapped.

The imperfect-nesting *split* heuristic (split fused bodies only when
the intermediate fits on-chip) is exposed as ``should_split`` and is
applied by the frontend when building fused programs (our bodies are
opaque tile-level functions, so splitting happens at construction time;
see DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from . import ir, rewrite


def _swap_xform(enc: int, k_first: int, k_second: int):
    """Callables written against (enc, A[k_first], B[k_second], tail) now
    receive (enc, B, A, tail)."""

    def edit(head):
        e = head[:enc]
        b = head[enc:enc + k_second]
        a = head[enc + k_second:enc + k_second + k_first]
        return tuple(e) + tuple(a) + tuple(b)

    return rewrite.prefix_preserving_tail(edit, enc + k_first + k_second)


def _is_unstrided_map(p: ir.Pattern) -> bool:
    return isinstance(p, ir.Map) and not p.strided


def _is_strided_fold(p: ir.Pattern) -> bool:
    return (isinstance(p, ir.MultiFold) and p.strided and p.is_fold
            and p.combine is not None)


def _is_unstrided_fold(p: ir.Pattern) -> bool:
    return (isinstance(p, ir.MultiFold) and not p.strided and p.is_fold
            and p.combine is not None)


def _is_strided_writeonce(p: ir.Pattern) -> bool:
    return isinstance(p, ir.MultiFold) and p.strided and p.combine is None


def _rule1(m: ir.Map, enc: int) -> Optional[ir.MultiFold]:
    """Move a strided fold out of an unstrided Map."""
    f = m.inner
    if not (_is_unstrided_map(m) and f is not None and _is_strided_fold(f)):
        return None
    if m.reads or f.reads or m.fn is not None or f.fn is not None:
        return None  # only the post-strip-mine canonical shape
    km, kf = len(m.domain), len(f.domain)
    xform = _swap_xform(enc, km, kf)

    new_range = tuple(m.domain) + tuple(f.range_shape)
    z_elem = np.asarray(f.init())
    z_new = np.broadcast_to(z_elem, new_range).copy()

    inner_map = ir.Map(
        domain=tuple(m.domain), elem_shape=tuple(f.range_shape),
        inner=rewrite.rewrap(f.inner, xform) if f.inner else None,
        name=m.name, dtype=m.dtype)

    return ir.MultiFold(
        domain=tuple(f.domain), range_shape=new_range,
        init=lambda _z=z_new: jnp.asarray(_z),
        out_index_map=lambda *s: (0,) * len(new_range),
        update_shape=new_range,
        combine=f.combine,  # shape-polymorphic elementwise lift
        inner=inner_map, strided=True,
        name=f.name, dtype=f.dtype)


def _rule2(f: ir.MultiFold, enc: int) -> Optional[ir.MultiFold]:
    """Move the (strided, write-once) outer of a tiled Map out of an
    unstrided fold."""
    w = f.inner
    if not (_is_unstrided_fold(f) and w is not None
            and _is_strided_writeonce(w)):
        return None
    if f.reads or w.reads or f.fn is not None or w.fn is not None:
        return None
    kf, kw = len(f.domain), len(w.domain)
    xform = _swap_xform(enc, kf, kw)

    # per-tile fold: reduces the tile slice across the unstrided domain
    z_full = np.asarray(f.init())
    upd = tuple(w.update_shape)

    def tile_init(_z=z_full, _u=upd):
        sl = tuple(slice(0, t) for t in _u)
        return jnp.asarray(_z[sl])  # uniform identity

    inner_fold = ir.MultiFold(
        domain=tuple(f.domain), range_shape=upd, init=tile_init,
        out_index_map=lambda *s: (0,) * len(upd), update_shape=upd,
        combine=f.combine,
        inner=rewrite.rewrap(w.inner, xform) if w.inner else None,
        name=f.name, dtype=f.dtype)

    def out_xf(head):
        # w.out_index_map was written against (enc, f, w); f is no longer
        # bound -- legal only if the map ignores f dims (checked by probe)
        return tuple(head[:enc]) + (0,) * kf + tuple(head[enc:enc + kw])

    from .affine import AffineMap
    probe = AffineMap.probe(w.out_index_map, enc + kf + kw)
    if any(probe.depends_on(enc + j) for j in range(kf)):
        return None  # output location depends on the fold index: no-go

    return ir.MultiFold(
        domain=tuple(w.domain), range_shape=tuple(w.range_shape),
        init=f.init,
        out_index_map=rewrite.wrap_index_map(
            w.out_index_map,
            rewrite.prefix_preserving_tail(out_xf, enc + kw)),
        update_shape=upd, combine=None, inner=inner_fold,
        strided=True, name=w.name, dtype=w.dtype)


def interchange(p: ir.Pattern, *, enc: int = 0,
                vmem_budget_words: int = 4 * 1024 * 1024) -> ir.Pattern:
    """Apply rules 1/2 wherever they match, innermost first, repeatedly.

    Rule 1 grows the accumulator from ``f.range`` to ``m.domain+f.range``
    (the paper: a (dist,label) pair becomes a tile of pairs); it is
    applied only when the grown intermediate fits on-chip -- the paper's
    split heuristic.
    """

    def visit(node: ir.Pattern, enc_: int) -> ir.Pattern:
        # rebuild children first (post-order) with correct enclosing rank
        updates = {}
        if node.inner is not None:
            updates["inner"] = visit(node.inner, enc_ + len(node.domain))
        rr, ch = [], False
        for a in node.accesses:
            if isinstance(a.src, ir.Pattern):
                ns = visit(a.src, enc_ + len(node.domain))
                if ns is not a.src:
                    rr.append(dataclasses.replace(a, src=ns))
                    ch = True
                    continue
            rr.append(a)
        if ch:
            updates["reads"] = tuple(rr)
        tl, ch2 = [], False
        for tc in node.loads:
            if isinstance(tc.src, ir.Pattern):
                ns = visit(tc.src, enc_ + len(node.domain))
                if ns is not tc.src:
                    tl.append(dataclasses.replace(tc, src=ns))
                    ch2 = True
                    continue
            tl.append(tc)
        if ch2:
            updates["tile_loads"] = tuple(tl)
        if updates:
            node = dataclasses.replace(node, **updates)

        out = _rule1(node, enc_) if isinstance(node, ir.Map) else None
        if out is not None:
            grown = int(np.prod(out.range_shape))
            if grown <= vmem_budget_words:
                return visit(out, enc_)  # re-check: rules may now fire above
            return node
        if isinstance(node, ir.MultiFold):
            out = _rule2(node, enc_)
            if out is not None:
                return visit(out, enc_)
        return node

    return visit(p, enc)


def should_split(intermediate_words: int,
                 vmem_budget_words: int = 4 * 1024 * 1024) -> bool:
    """The paper's split heuristic: split-and-interchange imperfectly
    nested patterns only when the intermediate created by the split is
    statically known to fit on-chip."""
    return intermediate_words <= vmem_budget_words
