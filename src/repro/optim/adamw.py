"""AdamW with global-norm clipping, cosine schedule, and optional int8
gradient compression with error feedback.

Optimizer *state sharding* (ZeRO-1) is expressed at the launch layer:
``repro.launch.shard_rules.opt_state_sharding`` additionally shards the
fp32 m/v (and the error-feedback residual) over the data(+pod) axes, so
each data-parallel rank keeps 1/N of the optimizer state -- on a
512-chip mesh that is the difference between replicating 12 bytes/param
and holding 12/32 bytes/param per chip.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    ef: Optional[Any] = None  # error-feedback residual (compression)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    compress_grads: bool = False  # int8 + error feedback


def init(params, cfg: AdamWConfig) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    ef = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
          if cfg.compress_grads else None)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros), ef)


def state_specs(param_specs, cfg: AdamWConfig) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), param_specs)
    ef = zeros if cfg.compress_grads else None
    return AdamWState(jax.ShapeDtypeStruct((), jnp.int32), zeros,
                      jax.tree.map(lambda x: x, zeros), ef)


def schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8; returns (q, scale)."""
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _compress_with_feedback(grads, ef):
    """int8 round-trip + error feedback.  On a real multi-pod deployment
    this wraps the inter-pod (DCN) gradient all-reduce: 4x fewer bytes on
    the slowest link; the residual keeps the estimator unbiased-ish."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return deq, g32 - deq

    pairs = jax.tree.map(one, grads, ef)
    deq = jax.tree.map(lambda p: p[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda p: p[1], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_ef


def update(grads, state: AdamWState, params,
           cfg: AdamWConfig) -> Tuple[Any, AdamWState]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    new_ef = state.ef
    if cfg.compress_grads:
        grads, new_ef = _compress_with_feedback(grads, state.ef)

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    lr = schedule(step, cfg)
    b1c = 1 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    trip = jax.tree.map(upd, params, grads, state.m, state.v)
    leaves = lambda i: jax.tree.map(lambda t: t[i], trip,
                                    is_leaf=lambda x: isinstance(x, tuple))
    return leaves(0), AdamWState(step, leaves(1), leaves(2), new_ef)
