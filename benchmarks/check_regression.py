"""CI perf-regression gate on modeled HBM traffic (pipeline fusion +
serving paged decode).

Compares a fresh ``BENCH_<rev>.json`` (``benchmarks/run.py --json``)
against the committed ``benchmarks/baseline_traffic.json`` and fails
(exit 1) when any pipeline's modeled traffic regresses by more than the
tolerance (default 5%).  The BENCH json's timing metadata
(``--repeat``/``--warmup``) and any measured/* (hybrid-DSE) rows are
echoed as notes so noisy measured configurations are visible in the
gate output.  Failures:

  * fused traffic words grew        (the megakernel moves more HBM)
  * unfused/fused ratio shrank      (the fusion win eroded)
  * a baseline pipeline disappeared (silent coverage loss)

New pipelines absent from the baseline are reported but do not fail --
commit a refreshed baseline (``--write-baseline``) in the same PR when
a change is intentional; the gate exists to make that an explicit,
reviewed step rather than silent drift.

Usage:
  python benchmarks/check_regression.py \
      --baseline benchmarks/baseline_traffic.json \
      --bench "bench-artifacts/BENCH_*.json" [--tolerance 0.05]
  python benchmarks/check_regression.py \
      --bench BENCH_x.json --write-baseline benchmarks/baseline_traffic.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Tuple

DEFAULT_TOLERANCE = 0.05


def load_doc(path_or_glob: str) -> Dict:
    """The newest (by mtime -- BENCH names carry a git rev, not a
    sortable stamp) matching BENCH json document; glob ok."""
    paths = glob.glob(path_or_glob) or [path_or_glob]
    newest = max(paths, key=lambda p: os.path.getmtime(p)
                 if os.path.exists(p) else 0)
    with open(newest) as f:
        return json.load(f)


def load_rows(path_or_glob: str) -> List[Dict]:
    return load_doc(path_or_glob).get("rows", [])


def timing_notes(doc: Dict) -> List[str]:
    """Human-readable notes about how the benchmark's wall times were
    taken (``run.py --repeat/--warmup``, recorded in the BENCH json) --
    printed with the gate result so a noisy measured configuration is
    visible next to the numbers it produced."""
    notes: List[str] = []
    t = doc.get("timing")
    if not t:
        return notes
    rep = t.get("repeat")
    rep_max = t.get("repeat_max", rep)
    rep_s = f"{rep}" if rep_max == rep else f"{rep}-{rep_max}"
    notes.append(
        f"timings: median of repeat={rep_s} "
        f"(warmup={t.get('warmup')} excluded) on "
        f"device={t.get('device', '?')}"
        + (" [interpret mode]" if t.get("interpret") else ""))
    measured = [r for r in doc.get("rows", [])
                if r.get("section") == "measured"]
    if measured:
        notes.append(f"{len(measured)} measured/* rows (hybrid DSE) in "
                     f"this benchmark")
        if int(t.get("repeat") or 0) < 3:
            notes.append(
                "measured rows taken with repeat < 3: medians may be "
                "noisy; prefer --repeat 3+ before trusting rankings")
    notes.extend(serving_notes(doc.get("rows", [])))
    notes.extend(accuracy_notes(doc))
    res = (doc.get("resilience") or {}).get("counts") or {}
    if res:
        # degradation is tolerated, never hidden: a run that
        # quarantined candidates or fell back to analytic plans says
        # so next to the numbers it produced
        summary = ", ".join(f"{k}={v}" for k, v in sorted(res.items()))
        notes.append(f"resilience degradation in this run: {summary}")
        faults = (doc.get("resilience") or {}).get("faults")
        if faults:
            notes.append(f"fault injection was active: "
                         f"REPRO_FAULTS={faults}")
    return notes


def accuracy_notes(doc: Dict) -> List[str]:
    """Cost-model accuracy gauges from the unified telemetry registry
    (``core.telemetry``, merged into the BENCH json by run.py): mean
    predicted-vs-measured relative drift and Spearman rank correlation
    per pattern family -- printed next to the gate verdicts so model
    quality is visible wherever traffic is gated."""
    notes: List[str] = []
    gauges = (doc.get("telemetry") or {}).get("gauges") or {}
    drift = {k.rsplit(".", 1)[1]: v for k, v in sorted(gauges.items())
             if k.startswith("model.drift.")}
    rho = {k.rsplit(".", 1)[1]: v for k, v in sorted(gauges.items())
           if k.startswith("model.spearman.")}
    for fam in sorted(set(drift) | set(rho)):
        parts = []
        if fam in drift:
            parts.append(f"drift={drift[fam] * 100:.0f}%")
        if fam in rho:
            parts.append(f"spearman={rho[fam]:+.2f}")
        notes.append(f"cost-model accuracy [{fam}]: " + ", ".join(parts))
    return notes


def serving_notes(rows: List[Dict]) -> List[str]:
    """``serving/*`` (shape-bucket warm start) rows summarized next to
    the gate result: per-cold-shape first-request latency before/after
    warm start, the bucket hit rate, and background promotions."""
    notes: List[str] = []
    for r in rows:
        if r.get("section") != "serving":
            continue
        name = r.get("name", "")
        if name == "serving/bucket_hit_rate":
            notes.append(f"serving bucket hit rate: {r.get('derived')}")
        elif name == "serving/background_promotions":
            notes.append(f"serving background re-tunes: "
                         f"{r.get('derived')}")
        elif name.startswith("serving/decode_ms_per_token/"):
            notes.append(f"serving decode {name.rsplit('/', 1)[1]}: "
                         f"{r.get('derived')}")
        elif name == "serving/continuous_occupancy":
            notes.append(f"serving continuous-batching occupancy: "
                         f"{r.get('derived')}")
        elif "cold_us" in r:
            shape = name.split("/", 1)[1]
            notes.append(
                f"serving cold-shape {shape}: first request "
                f"{r['cold_us']:.0f}us cold-explore -> "
                f"{r.get('warm_us', r.get('us', 0)):.0f}us "
                f"bucket-warm-start"
                + ("" if r.get("warm_start") else
                   " [NOT warm-started: no tuned bucket matched]"))
    return notes


def extract_traffic(rows: List[Dict]) -> Dict[str, Dict[str, float]]:
    """``fused/*`` rows -> {pipeline: {fused, unfused, ratio}}."""
    out: Dict[str, Dict[str, float]] = {}
    for r in rows:
        name = r.get("name", "")
        parts = name.split("/")
        if r.get("section") != "fused" or len(parts) != 3:
            continue
        _, pipeline, label = parts
        entry = out.setdefault(pipeline, {})
        if label in ("fused", "unfused") and "traffic_words" in r:
            entry[label] = float(r["traffic_words"])
        elif label == "traffic_ratio" and "traffic_ratio" in r:
            entry["ratio"] = float(r["traffic_ratio"])
    return {k: v for k, v in out.items() if "fused" in v}


def extract_decode(rows: List[Dict]) -> Dict[str, float]:
    """``serving/decode_*`` rows -> modeled decode-traffic summary
    (plain/paged words + ratio) and ms/token row presence flags."""
    out: Dict[str, float] = {}
    for r in rows:
        name = r.get("name", "")
        if name == "serving/decode_traffic/plain":
            out["plain"] = float(r["traffic_words"])
        elif name == "serving/decode_traffic/paged":
            out["paged"] = float(r["traffic_words"])
            if "traffic_ratio" in r:
                out["ratio"] = float(r["traffic_ratio"])
        elif name.startswith("serving/decode_ms_per_token/"):
            out[f"has_{name.rsplit('/', 1)[1]}_ms"] = 1.0
    return out


def compare_decode(baseline: Dict[str, float], fresh: Dict[str, float],
                   tolerance: float = DEFAULT_TOLERANCE
                   ) -> Tuple[List[str], List[str]]:
    """(failures, notes) for the serving paged-decode gate: the
    modeled paged decode traffic must not grow, the dense/paged
    traffic win must not erode, and the ms/token rows must keep being
    emitted (coverage, not value -- wall times are machine-noisy)."""
    failures: List[str] = []
    notes: List[str] = []
    if not baseline:
        if fresh:
            notes.append(
                "serving decode rows present but baseline has no "
                "serving_decode section -- refresh the baseline to "
                "start gating paged decode traffic")
        return failures, notes
    if not fresh:
        failures.append(
            "serving decode rows present in baseline but missing from "
            "the fresh benchmark (coverage loss)")
        return failures, notes
    if "paged" in baseline and "paged" in fresh \
            and fresh["paged"] > baseline["paged"] * (1.0 + tolerance):
        failures.append(
            f"serving paged decode traffic regressed "
            f"{baseline['paged']:.0f} -> {fresh['paged']:.0f} words "
            f"(> {tolerance:.0%} over baseline)")
    if "ratio" in baseline and "ratio" in fresh \
            and fresh["ratio"] < baseline["ratio"] * (1.0 - tolerance):
        failures.append(
            f"serving dense/paged traffic win eroded "
            f"{baseline['ratio']:.2f}x -> {fresh['ratio']:.2f}x "
            f"(> {tolerance:.0%} below baseline)")
    for key in ("has_plain_ms", "has_paged_ms"):
        if baseline.get(key) and not fresh.get(key):
            failures.append(
                f"serving/decode_ms_per_token/"
                f"{key[4:-3]} row disappeared (coverage loss)")
    return failures, notes


def compare(baseline: Dict[str, Dict[str, float]],
            fresh: Dict[str, Dict[str, float]],
            tolerance: float = DEFAULT_TOLERANCE
            ) -> Tuple[List[str], List[str]]:
    """(failures, notes) from baseline vs fresh per-pipeline traffic."""
    failures: List[str] = []
    notes: List[str] = []
    for name, base in sorted(baseline.items()):
        cur = fresh.get(name)
        if cur is None:
            failures.append(
                f"{name}: present in baseline but missing from the "
                f"fresh benchmark (coverage loss)")
            continue
        limit = base["fused"] * (1.0 + tolerance)
        if cur["fused"] > limit:
            failures.append(
                f"{name}: fused modeled traffic regressed "
                f"{base['fused']:.0f} -> {cur['fused']:.0f} words "
                f"(> {tolerance:.0%} over baseline)")
        if "ratio" in base and "ratio" in cur \
                and cur["ratio"] < base["ratio"] * (1.0 - tolerance):
            failures.append(
                f"{name}: fused/unfused win eroded "
                f"{base['ratio']:.2f}x -> {cur['ratio']:.2f}x "
                f"(> {tolerance:.0%} below baseline)")
    for name in sorted(set(fresh) - set(baseline)):
        notes.append(f"{name}: new pipeline, not in baseline -- refresh "
                     f"baseline_traffic.json to start gating it")
    return failures, notes


def write_baseline(path: str, fresh: Dict[str, Dict[str, float]],
                   decode: Dict[str, float] = None) -> None:
    doc = {"pipelines": {k: {kk: (int(vv) if kk != "ratio" else vv)
                             for kk, vv in sorted(v.items())}
                         for k, v in sorted(fresh.items())}}
    if decode:
        doc["serving_decode"] = {
            k: (v if k == "ratio" else int(v))
            for k, v in sorted(decode.items())}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote baseline for {len(fresh)} pipelines"
          + (" + serving decode traffic" if decode else "")
          + f" to {path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="benchmarks/baseline_traffic.json")
    ap.add_argument("--bench", required=True,
                    help="fresh BENCH_<rev>.json path or glob")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE)
    ap.add_argument("--write-baseline", default=None, metavar="PATH",
                    help="refresh the baseline from --bench and exit")
    args = ap.parse_args(argv)

    doc = load_doc(args.bench)
    for n in timing_notes(doc):
        print(f"note: {n}")
    if doc.get("error"):
        # run.py records a mid-run crash in the (still-valid) BENCH
        # json; its rows are partial -- neither gate against them nor
        # let --write-baseline silently shrink the gated pipeline set
        print(f"refusing: benchmark run recorded an error "
              f"({doc['error']}); rows are partial", file=sys.stderr)
        return 1
    fresh = extract_traffic(doc.get("rows", []))
    fresh_decode = extract_decode(doc.get("rows", []))
    if args.write_baseline:
        if not fresh:
            print("no fused/* traffic rows in the benchmark json",
                  file=sys.stderr)
            return 1
        write_baseline(args.write_baseline, fresh, fresh_decode)
        return 0

    with open(args.baseline) as f:
        base_doc = json.load(f)
    baseline = base_doc["pipelines"]
    if not fresh:
        print("REGRESSION GATE: no fused/* traffic rows in the fresh "
              "benchmark json (did the fused section run?)",
              file=sys.stderr)
        return 1
    failures, notes = compare(baseline, fresh, args.tolerance)
    dec_failures, dec_notes = compare_decode(
        base_doc.get("serving_decode", {}), fresh_decode,
        args.tolerance)
    failures.extend(dec_failures)
    notes.extend(dec_notes)
    for n in notes:
        print(f"note: {n}")
    if failures:
        print(f"REGRESSION GATE FAILED ({len(failures)}):",
              file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
        print("If intentional, refresh the baseline in this PR:\n"
              "  python benchmarks/check_regression.py --bench <BENCH.json>"
              " --write-baseline benchmarks/baseline_traffic.json",
              file=sys.stderr)
        return 1
    print(f"regression gate OK: {len(baseline)} pipelines within "
          f"{args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
