"""Serving path: one-call block prefill == token-by-token oracle, and
the mixed-prompt-length driver preserving request order.

``steps.make_cache_prefill_step`` runs attention families as a single
block ``decode_step`` and recurrent families as an in-jit token scan;
either way the resulting cache and next token must match feeding the
prompt one token at a time (the pre-ISSUE-8 serve loop).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import serve, steps
from repro.models import model


def _greedy(logits, cfg):
    logits = model.mask_vocab_pad(logits, cfg)
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["granite-3-2b",   # dense: block path
                                  "mamba2-370m"])   # ssm: scan path
def test_cache_prefill_matches_token_by_token(arch):
    cfg = get_config(arch, smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    B, S, room = 2, 8, 4
    rng = np.random.RandomState(1)
    prompt = jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32)

    prefill = jax.jit(steps.make_cache_prefill_step(cfg))
    nxt_a, cache_a = prefill(params, model.init_cache(cfg, B, S + room),
                             prompt, jnp.int32(0))

    cache_b = model.init_cache(cfg, B, S + room)
    for i in range(S):
        logits, cache_b = model.decode_step(params, cfg, cache_b,
                                            prompt[:, i:i + 1],
                                            jnp.int32(i))
    np.testing.assert_array_equal(np.asarray(nxt_a),
                                  np.asarray(_greedy(logits, cfg)))
    for a, b in zip(jax.tree_util.tree_leaves(cache_a),
                    jax.tree_util.tree_leaves(cache_b)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-4)


def test_prefill_chunks_at_ring_boundary():
    """A prompt longer than the KV ring serves through ``_prefill``'s
    chunking (a block write must not wrap the ring)."""
    cfg = get_config("granite-3-2b", smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    B, total = 1, 12
    ring = serve._ring_len(cfg, total)
    S = ring + 3 if ring < total else total   # force >= 2 chunks if we can
    rng = np.random.RandomState(2)
    prompt = jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32)

    prefill = jax.jit(steps.make_cache_prefill_step(cfg))
    nxt_a, _ = serve._prefill(prefill, params,
                              model.init_cache(cfg, B, total),
                              prompt, ring)

    cache_b = model.init_cache(cfg, B, total)
    for i in range(S):
        logits, cache_b = model.decode_step(params, cfg, cache_b,
                                            prompt[:, i:i + 1],
                                            jnp.int32(i))
    np.testing.assert_array_equal(np.asarray(nxt_a),
                                  np.asarray(_greedy(logits, cfg)))


def test_serve_mixed_prompt_lengths_preserve_order():
    """Requests re-grouped by prompt length come back in input order:
    the rows sharing the uniform run's length generate identical
    tokens, regardless of which group they decoded in."""
    uniform = serve.serve("granite-3-2b", True, 3, 6, 2)
    mixed = serve.serve("granite-3-2b", True, 3, 6, 2,
                        prompt_lens=(6, 4, 6))
    assert mixed.shape == (3, 2)
    np.testing.assert_array_equal(mixed[[0, 2]], uniform[[0, 2]])
