"""Automated tile-size selection -- the paper's stated future work.

    "In future work, tile sizes for all pattern dimensions will instead
     be determined by the compiler through automated tile size selection
     using modeling and design space exploration."  (paper, §4)

This module is that compiler pass for the GEMM template: enumerate
MXU-aligned candidate tile triples, price each with the PPL cost model
(main-memory traffic via ``core.cost.traffic`` on the tiled IR +
metapipeline overlap), reject candidates whose buffers exceed the VMEM
budget (``core.memory.plan_memory``), and return the argmin.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core.cost import VMEM_BYTES, traffic
from repro.core.memory import plan_memory
from repro.core.strip_mine import tile
from repro.patterns.analytics import gemm

MXU = 128
LANE = 8


@dataclasses.dataclass
class TileChoice:
    block_m: int
    block_n: int
    block_k: int
    traffic_words: int
    vmem_bytes: int


def _candidates(dim: int, align: int) -> List[int]:
    out = []
    c = align
    while c <= dim:
        if dim % c == 0:
            out.append(c)
        c *= 2
    return out or [dim]


def select_gemm_tiles(m: int, n: int, k: int, *,
                      vmem_budget: int = VMEM_BYTES,
                      align: int = MXU) -> TileChoice:
    """DSE over (bm, bn, bk): minimize modeled HBM traffic of the tiled
    IR subject to the VMEM budget."""
    best: Optional[TileChoice] = None
    for bm in _candidates(m, min(align, m)):
        for bn in _candidates(n, min(align, n)):
            for bk in _candidates(k, min(align, k)):
                p, sizes, _, _ = gemm(m, n, k, bm, bn, bk)
                t = tile(p, sizes)
                plan = plan_memory(t, vmem_budget_bytes=vmem_budget)
                if not plan.fits:
                    continue
                tr = traffic(t)
                cand = TileChoice(bm, bn, bk, tr.total_reads,
                                  plan.total_bytes)
                if best is None or cand.traffic_words < best.traffic_words \
                        or (cand.traffic_words == best.traffic_words
                            and cand.vmem_bytes > best.vmem_bytes):
                    best = cand
    assert best is not None, "no candidate fits VMEM"
    return best


def tuned_matmul(x, y, **kw):
    """matmul with cost-model-selected block sizes."""
    from repro.kernels.matmul import matmul

    m, k = x.shape
    _, n = y.shape
    c = select_gemm_tiles(m, n, k)
    return matmul(x, y, block_m=c.block_m, block_n=c.block_n,
                  block_k=c.block_k, **kw)
