"""Measured timing harness for lowered kernels (hybrid DSE, ROADMAP
"price with measured Pallas timings").

The analytic cost model (``core.cost``) prices candidates in modeled
HBM seconds; this module supplies the *measured* side of the hybrid
analytic->measured exploration:

  * ``measure``    -- median-of-k wall time of a zero-arg callable with
    ``jax.block_until_ready`` on every call; the first ``warmup`` calls
    (compilation + autotuning) are executed but excluded, so reported
    seconds are steady-state, never compile time.
  * ``TimingDB``   -- persistent device-keyed measurement store living
    alongside the DSE tuning cache (``REPRO_TIMING_DB``, defaulting to
    a sibling of ``REPRO_DSE_CACHE``): a candidate timed once is never
    lowered or executed again on that device.
  * ``synth_inputs`` -- deterministic concrete arrays for a pattern's
    symbolic ``ir.Tensor`` inputs (timing needs values, not semantics).

On CPU the repo's Pallas kernels run in ``interpret=True`` mode, so
timings are interpreter steady-state costs -- honest *relative* prices
for ranking candidates, not TPU absolutes.  The DB key carries both the
device kind and the interpret flag, so interpreter medians can never
masquerade as compiled-TPU medians after a device change.
"""
from __future__ import annotations

import dataclasses
import os
import statistics
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from . import ir, resilience, telemetry


# --------------------------------------------------------------------------
# Device identity
# --------------------------------------------------------------------------


def device_kind() -> str:
    """Normalized device identity ("cpu", "tpu-v5e", ...) keying the
    timing DB and the calibration profile."""
    try:
        import jax
        d = jax.devices()[0]
        kind = getattr(d, "device_kind", "") or d.platform
        return str(kind).strip().lower().replace(" ", "-")
    except Exception:
        return "unknown"


def interpret_mode() -> bool:
    """True when the repo's Pallas kernels run interpreted (CPU
    container); mirrored into every timing-DB key."""
    from .codegen_pallas import INTERPRET
    return bool(INTERPRET)


# --------------------------------------------------------------------------
# The measurement itself
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Measurement:
    """Steady-state wall time of one callable on one device."""

    median_s: float
    mean_s: float
    min_s: float
    max_s: float
    repeat: int
    warmup: int
    device: str = "unknown"
    interpret: bool = True
    cached: bool = False   # served from the TimingDB, nothing executed

    @property
    def spread(self) -> float:
        """(max - min) / median -- the noise figure surfaced next to
        measured rows in the CI gate output."""
        return (self.max_s - self.min_s) / max(self.median_s, 1e-12)

    def to_json(self) -> Dict:
        return {"median_s": self.median_s, "mean_s": self.mean_s,
                "min_s": self.min_s, "max_s": self.max_s,
                "repeat": self.repeat, "warmup": self.warmup,
                "device": self.device, "interpret": self.interpret}

    @classmethod
    def from_json(cls, d: Dict) -> "Measurement":
        return cls(median_s=float(d["median_s"]),
                   mean_s=float(d["mean_s"]),
                   min_s=float(d["min_s"]), max_s=float(d["max_s"]),
                   repeat=int(d["repeat"]), warmup=int(d["warmup"]),
                   device=str(d.get("device", "unknown")),
                   interpret=bool(d.get("interpret", True)),
                   cached=True)


def measure(fn: Callable[[], object], *, warmup: int = 1,
            repeat: int = 5) -> Measurement:
    """Median-of-``repeat`` wall seconds of ``fn()``.

    Every call is fenced with ``jax.block_until_ready`` (async dispatch
    would otherwise time the enqueue, not the kernel).  The first
    ``warmup`` calls run but are *excluded* -- they absorb tracing,
    compilation and first-touch allocation, the costs the old
    ``benchmarks/run.py --reps=1`` path conflated with steady state.
    """
    import jax

    if repeat < 1:
        raise ValueError(f"repeat must be >= 1, got {repeat}")
    if warmup < 0:
        raise ValueError(f"warmup must be >= 0, got {warmup}")
    # chaos hook: REPRO_FAULTS=time:<p> makes this measurement fail
    # deterministically so the quarantine path can be exercised
    resilience.inject("time", "measure.measure")
    with telemetry.span("measure.measure", warmup=warmup,
                        repeat=repeat) as sp:
        for _ in range(warmup):
            jax.block_until_ready(fn())
        times = []
        for _ in range(repeat):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            times.append(time.perf_counter() - t0)
        m = Measurement(median_s=statistics.median(times),
                        mean_s=sum(times) / len(times),
                        min_s=min(times), max_s=max(times),
                        repeat=repeat, warmup=warmup,
                        device=device_kind(),
                        interpret=interpret_mode())
        sp.set(median_s=m.median_s, spread=m.spread)
    return m


# --------------------------------------------------------------------------
# Persistent timing DB
# --------------------------------------------------------------------------


def cache_sibling_path(name: str,
                       env_var: Optional[str] = None) -> str:
    """Shared path resolution for every persistent store (tuning
    cache, timing DB, calibration profile): the store's own env var if
    set, else a sibling of ``REPRO_DSE_CACHE`` (the stores persist
    together, e.g. under one CI cache key), else the XDG cache dir."""
    if env_var:
        env = os.environ.get(env_var)
        if env:
            return env
    dse_cache = os.environ.get("REPRO_DSE_CACHE")
    if dse_cache:
        return os.path.join(os.path.dirname(dse_cache) or ".", name)
    base = os.environ.get("XDG_CACHE_HOME",
                          os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(base, "repro", name)


# atomic JSON write, re-exported for back-compat (the crash-safe
# store layer in ``core.resilience`` owns the implementation now)
atomic_write_json = resilience.atomic_write_json


def default_db_path() -> str:
    return cache_sibling_path("timing_db.json", "REPRO_TIMING_DB")


class TimingDB:
    """On-disk measurement store keyed by (device, interpret, key).

    Same contract as the DSE ``TuningCache``: crash-safe checksummed
    JSON (``resilience.load_store``: a truncated or corrupt file is
    quarantined to ``<path>.corrupt`` with a warning and the DB
    rebuilds fresh), lock-protected read-modify-write on put, and the
    DB only ever accelerates re-exploration -- it is never a
    correctness dependency.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_db_path()
        self._data: Optional[Dict[str, Dict]] = None

    @staticmethod
    def full_key(key: str, *, device: Optional[str] = None,
                 interpret: Optional[bool] = None) -> str:
        device = device_kind() if device is None else device
        interp = interpret_mode() if interpret is None else interpret
        return f"{device}|interp={int(interp)}|{key}"

    def _load(self) -> Dict[str, Dict]:
        if self._data is None:
            self._data = resilience.load_store(self.path,
                                               label="timing DB")
        return self._data

    def get(self, key: str) -> Optional[Measurement]:
        d = self._load().get(self.full_key(key))
        if d is None:
            return None
        try:
            return Measurement.from_json(d)
        except (KeyError, TypeError, ValueError):
            return None

    def put(self, key: str, m: Measurement) -> None:
        mine = self._load()
        mine[self.full_key(key)] = m.to_json()

        def merge(data: Dict) -> None:
            data[self.full_key(key)] = m.to_json()

        # locked read-modify-write: a concurrent process's entries
        # survive this put (and land in our in-memory view)
        self._data = resilience.locked_update(
            self.path, merge, label="timing DB", prefix=".timing_db.")
        self._data.update(mine)

    def clear(self) -> None:
        self._data = {}
        try:
            os.unlink(self.path)
        except OSError:
            pass


def resolve_db(db) -> Optional[TimingDB]:
    """Resolve the ``timing_db`` option (the same convention
    ``dse.Options`` carries): ``None`` -> default on-disk DB,
    path/TimingDB -> that DB, ``False`` -> no persistence."""
    if db is False:
        return None
    if db is None:
        return TimingDB()
    if isinstance(db, str):
        return TimingDB(db)
    return db


# historical private name, kept for existing callers
_resolve_db = resolve_db


def timed(key: str, make_fn: Callable[[], Callable[[], object]], *,
          db=None, warmup: int = 1, repeat: int = 5) -> Measurement:
    """Measure ``make_fn()()`` under ``key``, memoized in the DB.

    ``make_fn`` is a *thunk returning the callable*: on a DB hit
    nothing is built, so a cache-warm exploration does zero lowering
    and zero execution.
    """
    tdb = _resolve_db(db)
    if tdb is not None:
        hit = tdb.get(key)
        if hit is not None:
            telemetry.count("measure.db_hits")
            return hit
    with telemetry.span("measure.timed", key=key[-32:]) as sp:
        m = measure(make_fn(), warmup=warmup, repeat=repeat)
        sp.set(median_s=m.median_s)
    if tdb is not None:
        tdb.put(key, m)
    return m


# --------------------------------------------------------------------------
# Input synthesis
# --------------------------------------------------------------------------


def synth_inputs(tensors: Sequence[ir.Tensor], *, seed: int = 0
                 ) -> Dict[str, "np.ndarray"]:
    """Deterministic concrete arrays for symbolic pattern inputs.

    Timing only needs well-typed dense data: floats are standard
    normals, ints draw from a small non-negative range (safe for key
    tensors -- the CAM template's one-hot drops out-of-range keys
    rather than crashing).  Same seed -> bit-identical inputs, so DB
    entries from different sessions timed the same computation.
    """
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    out = {}
    for t in tensors:
        dt = np.dtype(t.dtype)
        shape = tuple(t.shape)
        if np.issubdtype(dt, np.integer):
            val = rng.integers(0, 8, size=shape).astype(dt)
        elif np.issubdtype(dt, np.bool_):
            val = rng.integers(0, 2, size=shape).astype(dt)
        else:
            val = rng.standard_normal(shape).astype(dt)
        out[t.name] = jnp.asarray(val)
    return out


def _rank(xs: Sequence[float]) -> Tuple[float, ...]:
    """Average ranks (ties averaged), 1-based."""
    order = sorted(range(len(xs)), key=lambda i: xs[i])
    ranks = [0.0] * len(xs)
    i = 0
    while i < len(order):
        j = i
        while j + 1 < len(order) and xs[order[j + 1]] == xs[order[i]]:
            j += 1
        avg = (i + j) / 2.0 + 1.0
        for k in range(i, j + 1):
            ranks[order[k]] = avg
        i = j + 1
    return tuple(ranks)


def spearman(xs: Sequence[float], ys: Sequence[float]) -> float:
    """Spearman rank correlation (ties averaged).

    The quantity ``benchmarks/run.py --measure`` tables per workload:
    how well the (calibrated or uncalibrated) analytic candidate
    ranking matches the measured one.  Degenerate inputs (constant
    vectors, < 2 points) return 1.0 when the rankings trivially agree
    and 0.0 otherwise.
    """
    if len(xs) != len(ys):
        raise ValueError(f"length mismatch: {len(xs)} vs {len(ys)}")
    n = len(xs)
    if n < 2:
        return 1.0
    rx, ry = _rank(xs), _rank(ys)
    mx = sum(rx) / n
    my = sum(ry) / n
    cov = sum((a - mx) * (b - my) for a, b in zip(rx, ry))
    vx = sum((a - mx) ** 2 for a in rx)
    vy = sum((b - my) ** 2 for b in ry)
    if vx == 0 and vy == 0:
        return 1.0
    if vx == 0 or vy == 0:
        return 0.0
    return cov / (vx * vy) ** 0.5
