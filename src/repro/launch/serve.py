"""Serving driver: batched one-call prefill + decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
        --smoke --batch 4 --prompt-len 32 --gen 16

Demonstrates the full inference path (the ``decode_*`` dry-run shapes
lower exactly this ``serve_step``): the whole prompt prefills the cache
in a single jitted call (``steps.make_cache_prefill_step`` -- block
decode for attention families, an in-jit token scan for recurrent
ones), then ``--gen`` tokens greedy-decode one step at a time.

``--prompt-lens 24,100,100,360`` serves a mixed batch: requests are
grouped by prompt length and each group prefills in one call.  With
``--bucketing`` the tuning plans backing each group's attention shape
resolve through the shape-bucket layer (``core.buckets``): a cold
prompt length whose bucket is already tuned is served a warm-start
plan immediately (zero foreground lowering) while a bounded background
re-tune promotes the certified exact-shape winner into the cache.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.launch import steps as steps_mod
from repro.models import model


def _prefill(prefill_fn, params, cache, prompt, ring: int,
             index0: int = 0):
    """Prefill ``prompt`` into ``cache`` starting at ``index0``,
    chunking at the KV ring boundary (a block write must not wrap)."""
    plen = prompt.shape[1]
    i, nxt = 0, None
    while i < plen:
        chunk = min(plen - i, ring - ((index0 + i) % ring))
        nxt, cache = prefill_fn(params, cache, prompt[:, i:i + chunk],
                                jnp.int32(index0 + i))
        i += chunk
    return nxt, cache


def _ring_len(cfg, max_len: int) -> int:
    """Slot count of the KV ring buffer (= prompt-chunk bound); the
    recurrent scan path has no ring, so any chunk length works."""
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        return model.cache_specs(cfg, 1, max_len)["k"].shape[3]
    return max_len


def _resolve_group_plans(cfg, lengths: Sequence[int], max_len: int
                         ) -> List[Dict]:
    """Resolve the DSE attention plan for each prompt-length group
    through the shape-bucket layer.  Returns per-group provenance:
    did the plan come from the exact tuning cache, a bucket warm
    start, or a fresh exploration?"""
    from repro.core import buckets
    from repro.core.options import Options
    from repro.kernels import ops

    opts = Options(bucketing=True)
    head_dim = cfg.head_dim or (cfg.d_model // max(cfg.n_heads, 1))
    rows = []
    for plen in lengths:
        t0 = time.time()
        _, plan = ops.resolve_plan("attention", int(plen), int(max_len),
                                   int(head_dim), options=opts)
        rows.append({
            "prompt_len": int(plen),
            "resolve_s": time.time() - t0,
            "warm_start": bool(plan.warm_start),
            "bucket": plan.bucket,
            "cached": bool(plan.cached),
            "sizes": {k: tuple(v) for k, v in plan.sizes.items()},
        })
    rows.append({"bucket_stats": buckets.stats(),
                 "bucket_hit_rate": buckets.hit_rate()})
    return rows


def serve(arch: str, smoke: bool, batch: int, prompt_len: int,
          gen: int, seed: int = 0,
          prompt_lens: Optional[Sequence[int]] = None,
          bucketing: bool = False) -> np.ndarray:
    """Serve ``batch`` requests; returns the (batch, gen) generated
    tokens (requests keep their input order even when mixed prompt
    lengths are re-grouped internally)."""
    cfg = get_config(arch, smoke=smoke)
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    lens = list(prompt_lens) if prompt_lens else [prompt_len] * batch
    if len(lens) != batch:
        raise ValueError(f"--prompt-lens gave {len(lens)} lengths for "
                         f"--batch {batch}")
    max_len = max(lens) + gen
    prefill_fn = jax.jit(steps_mod.make_cache_prefill_step(cfg),
                         donate_argnums=(1,))
    step_fn = jax.jit(steps_mod.make_serve_step(cfg), donate_argnums=(1,))

    rng = np.random.RandomState(seed)
    tok_shape = ((batch, max(lens), cfg.n_codebooks) if cfg.n_codebooks
                 else (batch, max(lens)))
    prompt_pool = rng.randint(0, cfg.vocab, tok_shape)

    # group requests by prompt length: each group prefills its whole
    # prompt in one call (one compile per distinct length)
    groups: Dict[int, List[int]] = {}
    for r, ln in enumerate(lens):
        groups.setdefault(ln, []).append(r)

    if bucketing:
        for row in _resolve_group_plans(cfg, sorted(groups), max_len):
            print("plan:", row)

    out = np.zeros((batch, gen), np.int64)
    prefill_s = decode_s = 0.0
    for ln, rows in sorted(groups.items()):
        gb = len(rows)
        prompt = jnp.asarray(prompt_pool[rows][:, :ln], jnp.int32)
        cache = model.init_cache(cfg, gb, ln + gen)
        ring = _ring_len(cfg, ln + gen)

        t0 = time.time()
        nxt, cache = _prefill(prefill_fn, params, cache, prompt, ring)
        jax.block_until_ready(nxt)
        prefill_s += time.time() - t0

        group_out = []
        t0 = time.time()
        for i in range(ln, ln + gen):
            if cfg.n_codebooks:
                tok = nxt.reshape(gb, 1, cfg.n_codebooks)
            else:
                tok = nxt.reshape(gb, 1)
            nxt, cache = step_fn(params, cache, tok, jnp.int32(i))
            group_out.append(np.asarray(nxt))
        decode_s += time.time() - t0

        toks = np.stack(group_out, axis=1)        # (gb, gen[, ncb])
        if cfg.n_codebooks:
            toks = toks[..., 0]                   # report codebook 0
        out[rows] = toks

    n_groups = len(groups)
    print(f"prefill {sorted(groups)} ({n_groups} group"
          f"{'s' if n_groups > 1 else ''}): {prefill_s:.2f}s; "
          f"decode {gen} tokens: {decode_s:.2f}s "
          f"({decode_s / max(gen, 1) * 1e3:.0f} ms/token)")
    return out


def _parse_lens(text: Optional[str]) -> Optional[Tuple[int, ...]]:
    if not text:
        return None
    return tuple(int(x) for x in text.split(",") if x.strip())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--prompt-lens", type=str, default=None,
                    help="comma-separated per-request prompt lengths "
                         "(mixed batch; overrides --prompt-len)")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--bucketing", action="store_true",
                    help="resolve tuning plans through the shape-bucket "
                         "warm-start layer and print their provenance")
    args = ap.parse_args()
    toks = serve(args.arch, args.smoke, args.batch, args.prompt_len,
                 args.gen, prompt_lens=_parse_lens(args.prompt_lens),
                 bucketing=args.bucketing)
    print("generated token block:", toks.shape)


if __name__ == "__main__":
    main()
