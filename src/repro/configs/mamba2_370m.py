"""Mamba2-370M [arXiv:2405.21060]: attention-free SSD."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm", n_layers=48, d_model=1024,
    vocab=50280, vocab_pad=152, ssm_state=128, ssm_heads=32, ssm_head_dim=64,
    ssm_conv=4, ssm_expand=2)

SMOKE = CONFIG.with_(vocab_pad=0, n_layers=2, d_model=64, vocab=256, ssm_state=16,
                     ssm_heads=4, ssm_head_dim=32, remat=False)
