"""Automated GEMM tile-size selection -- thin front-end over the
pattern-generic DSE subsystem (``repro.core.dse``).

    "In future work, tile sizes for all pattern dimensions will instead
     be determined by the compiler through automated tile size selection
     using modeling and design space exploration."  (paper, §4)

Historically this module *was* that compiler pass, hardcoded to the
GEMM template.  The exploration loop (candidate enumeration, cost-model
pricing, VMEM pruning, argmin, tuning cache) now lives in
``repro.core.dse`` and serves every Pallas kernel's ``auto_tile=True``
path; this front-end only adapts the GEMM tile plan to the historical
``TileChoice`` API.
"""
from __future__ import annotations

import dataclasses
from typing import Union

from repro.core.dse import MXU, SUBLANE, TuningCache, select_gemm_blocks

LANE = SUBLANE  # historical alias


@dataclasses.dataclass
class TileChoice:
    block_m: int
    block_n: int
    block_k: int
    traffic_words: int
    vmem_bytes: int


def select_gemm_tiles(m: int, n: int, k: int, *,
                      vmem_budget: Union[None, int] = None,
                      align: Union[None, int] = None,
                      cache: Union[None, bool, str, TuningCache] = None,
                      measure: Union[None, str] = None,
                      policy=None, options=None) -> TileChoice:
    """DSE over (bm, bn, bk): minimize modeled HBM traffic of the tiled
    IR subject to the VMEM budget (delegates to ``core.dse.explore``;
    ``measure="top_k"`` backs the choice with real timings; ``policy``
    bounds the measured exploration; ``options`` (a ``dse.Options``)
    packs any exploration option)."""
    (bm, bn, bk), plan = select_gemm_blocks(
        m, n, k, vmem_budget=vmem_budget, align=align, cache=cache,
        measure=measure, policy=policy, options=options)
    return TileChoice(bm, bn, bk, plan.traffic_words, plan.vmem_bytes)


def tuned_matmul(x, y, **kw):
    """matmul with cost-model-selected block sizes."""
    from repro.kernels.matmul import matmul

    return matmul(x, y, auto_tile=True, **kw)
