"""Unified model API: every architecture family behind one interface.

    shapes  = model.param_shapes(cfg)
    params  = model.init_params(cfg, key)        (smoke/real runs)
    specs   = model.param_specs(cfg)             (dry-run, no alloc)
    logits  = model.forward(params, cfg, batch)
    loss    = model.loss(params, cfg, batch)
    logits, cache = model.decode_step(params, cfg, cache, tokens, idx)
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import hybrid as hy
from . import layers as L
from . import ssm as ssm_mod
from . import transformer as tr
from .config import ModelConfig
from .sharding import hint_first

Params = Dict[str, Any]
Batch = Dict[str, jax.Array]


# --------------------------------------------------------------- shapes
def param_shapes(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        return tr.param_shapes(cfg)
    if cfg.family == "ssm":
        d, v = cfg.d_model, cfg.padded_vocab
        shapes = {
            "embed": ((v, d), "embed"),
            "lm_head": ((d, v), "dense"),
            "final_norm": ((d,), "zeros"),
        }
        shapes.update(ssm_mod.block_param_shapes(cfg, cfg.n_layers, "m_"))
        return shapes
    if cfg.family == "hybrid":
        return hy.param_shapes(cfg)
    raise KeyError(cfg.family)


def param_specs(cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    out = {}
    for k, (s, kind) in param_shapes(cfg).items():
        d = jnp.float32 if k in ("m_A_log", "m_D", "m_dt_bias") else dt
        out[k] = jax.ShapeDtypeStruct(s, d)
    return out


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = jnp.dtype(cfg.dtype)
    out = {}
    shapes = param_shapes(cfg)
    keys = jax.random.split(key, len(shapes))
    for (name, (shape, kind)), k in zip(sorted(shapes.items()), keys):
        d = jnp.float32 if name in ("m_A_log", "m_D", "m_dt_bias") else dt
        if kind == "zeros":
            out[name] = jnp.zeros(shape, d)
        elif kind == "embed":
            out[name] = L.embed_init(k, shape, d)
        else:
            in_axis = -2 if len(shape) >= 2 else 0
            out[name] = L.dense_init(k, shape, in_axis=in_axis, dtype=d)
    if "m_A_log" in out:  # stable decay init: A in [-e, -1/e]
        out["m_A_log"] = jnp.zeros_like(out["m_A_log"]) - 0.5
    return out


# -------------------------------------------------------------- forward
def _ssm_forward(params: Params, cfg: ModelConfig,
                 tokens: jax.Array) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    stacks = {k: v for k, v in params.items() if k.startswith("m_")}

    def body(x, slc):
        x, _ = ssm_mod.block_forward(slc, x, cfg, prefix="m_")
        return x, None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = L.scan_layers(body, x, stacks, cfg.unroll)
    x = L.rms_norm(x, params["final_norm"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def forward(params: Params, cfg: ModelConfig, batch: Batch) -> jax.Array:
    tokens = batch["tokens"]
    if cfg.family in ("dense", "moe", "audio"):
        return tr.forward(params, cfg, tokens)
    if cfg.family == "vlm":
        return tr.forward(params, cfg, tokens,
                          prefix_embeds=batch.get("prefix_embeds"))
    if cfg.family == "ssm":
        return _ssm_forward(params, cfg, tokens)
    if cfg.family == "hybrid":
        return hy.forward(params, cfg, tokens)
    raise KeyError(cfg.family)


def mask_vocab_pad(logits: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Pad vocab columns never win: masked to -1e30 (exact for both
    softmax-xent and argmax decode)."""
    if cfg.vocab_pad == 0:
        return logits
    col = jnp.arange(logits.shape[-1])
    return jnp.where(col >= cfg.vocab, jnp.asarray(-1e30, logits.dtype),
                     logits)


def loss(params: Params, cfg: ModelConfig, batch: Batch) -> jax.Array:
    logits = mask_vocab_pad(forward(params, cfg, batch), cfg)
    if cfg.n_codebooks:
        logits = hint_first(logits, [("data", None, None, "model"),
                                     ("data", "model", None, None)])
    else:
        logits = hint_first(logits, [("data", None, "model"),
                                     ("data", "model", None)])
    labels = batch["labels"]
    if cfg.family == "vlm" and "prefix_embeds" in batch:
        # loss only on text positions (frontend prefix is unlabeled)
        p = batch["prefix_embeds"].shape[1]
        logits = logits[:, p:]
    if cfg.n_codebooks:
        # (B, S, n_cb, V) vs labels (B, S, n_cb)
        return L.softmax_xent(logits, labels)
    return L.softmax_xent(logits, labels)


# --------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        return tr.init_cache(cfg, batch, max_len)
    if cfg.family == "ssm":
        return {"ssm": ssm_mod.init_state(cfg, batch)}
    if cfg.family == "hybrid":
        return hy.init_cache(cfg, batch, max_len)
    raise KeyError(cfg.family)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        return tr.cache_specs(cfg, batch, max_len)
    if cfg.family == "ssm":
        return {"ssm": ssm_mod.state_specs(cfg, batch)}
    if cfg.family == "hybrid":
        return hy.cache_specs(cfg, batch, max_len)
    raise KeyError(cfg.family)


def _ssm_decode(params: Params, cfg: ModelConfig, cache: Dict,
                tokens: jax.Array, index: jax.Array):
    x = jnp.take(params["embed"], tokens, axis=0)
    stacks = {k: v for k, v in params.items() if k.startswith("m_")}

    def body(x, slices):
        slc, conv_st, ssm_st = slices
        x, st = ssm_mod.block_forward(
            slc, x, cfg, state={"conv": conv_st, "ssm": ssm_st},
            prefix="m_")
        return x, (st["conv"], st["ssm"])

    x, (nc, ns) = L.scan_layers(
        body, x, (stacks, cache["ssm"]["conv"], cache["ssm"]["ssm"]),
        cfg.unroll)
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, {"ssm": {"conv": nc, "ssm": ns}}


def decode_step(params: Params, cfg: ModelConfig, cache: Dict,
                tokens: jax.Array, index: jax.Array):
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        return tr.decode_step(params, cfg, cache, tokens, index)
    if cfg.family == "ssm":
        return _ssm_decode(params, cfg, cache, tokens, index)
    if cfg.family == "hybrid":
        return hy.decode_step(params, cfg, cache, tokens, index)
    raise KeyError(cfg.family)
