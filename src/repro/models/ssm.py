"""Mamba-2 (SSD) blocks, pure JAX with scan-over-layers.

The SSD sequence computation is the strip-mined MultiFold of the paper
(kernels/ssd_scan.py is the Pallas realization); this module provides
the full-sequence chunked form used for training/prefill and the
recurrent single-step form used for decode, plus the block plumbing
(in-proj, causal conv, gating, out-proj) from arXiv:2405.21060.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ModelConfig
from .sharding import hint

Params = Dict[str, Any]


def block_param_shapes(cfg: ModelConfig, nl: int, prefix: str = ""
                       ) -> Dict[str, Tuple[Tuple[int, ...], str]]:
    d, di, ns, h = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads)
    k = cfg.ssm_conv
    p = prefix
    return {
        f"{p}ln": ((nl, d), "zeros"),
        f"{p}in_proj": ((nl, d, 2 * di + 2 * ns + h), "dense"),
        f"{p}conv_w": ((nl, k, di + 2 * ns), "dense"),
        f"{p}A_log": ((nl, h), "zeros"),       # A = -exp(A_log)
        f"{p}D": ((nl, h), "zeros"),
        f"{p}dt_bias": ((nl, h), "zeros"),
        f"{p}gate_ln": ((nl, di), "zeros"),
        f"{p}out_proj": ((nl, di, d), "dense"),
    }


def _split_proj(z: jax.Array, cfg: ModelConfig):
    di, ns, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    xz, rest = z[..., :2 * di], z[..., 2 * di:]
    x_in, gate = xz[..., :di], xz[..., di:]
    B = rest[..., :ns]
    C = rest[..., ns:2 * ns]
    dt = rest[..., 2 * ns:]
    return x_in, gate, B, C, dt


SSD_CHUNK = 64  # tile size: picked by the Fig-5c-style cost model sweep
                # (EXPERIMENTS.md §Perf mamba2 iteration 1)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int = None):
    if chunk is None:
        chunk = SSD_CHUNK
    """Full-sequence SSD, chunked (matmul) form -- jnp implementation of
    the same algorithm as kernels/ssd_scan.py, used inside scan/jit.

    x: (b, s, h, dh); dt: (b, s, h); A: (h,); B, C: (b, s, n).
    Returns y (b, s, h, dh) and the final state (b, h, n, dh)."""
    b, s, h, dh = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    xf = x.astype(jnp.float32).reshape(b, nc, chunk, h, dh)
    xf = hint(xf, "data", None, None, "model", None)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, h)
    dtf = hint(dtf, "data", None, None, "model")
    Bf = B.astype(jnp.float32).reshape(b, nc, chunk, n)
    Cf = C.astype(jnp.float32).reshape(b, nc, chunk, n)

    idx = jnp.arange(chunk)
    lmask = idx[:, None] >= idx[None, :]

    def chunk_body(hprev, inp):
        # one strided iteration of the tiled MultiFold: all (L,L,h)
        # decay intermediates live only inside this chunk (rematted).
        # Heads shard over "model"; decay/score temps in bf16 with f32
        # accumulation on the matmuls (the Pallas kernel's numerics).
        xc, dtc, Bc, Cc = inp              # (b,L,h,dh) (b,L,h) (b,L,n) x2
        sA = A[None, None, :] * dtc        # (b,L,h)
        cum = jnp.cumsum(sA, axis=1)
        total = cum[:, -1, :]              # (b,h)
        Mdec = jnp.where(lmask[None, :, :, None],
                         jnp.exp(cum[:, :, None, :] - cum[:, None, :, :]),
                         0.0)              # (b,L,L,h)
        Mdec = hint(Mdec, "data", None, None, "model")
        scores = jnp.einsum("bln,bmn->blm", Cc, Bc,
                            preferred_element_type=jnp.float32)
        SM = (scores[..., None] * Mdec).astype(jnp.bfloat16)
        xdt = (dtc[..., None] * xc).astype(jnp.bfloat16)   # (b,L,h,dh)
        y_intra = jnp.einsum("blmh,bmhd->blhd", SM, xdt,
                             preferred_element_type=jnp.float32)
        y_state = jnp.einsum("bln,blh,bhnd->blhd", Cc,
                             jnp.exp(cum), hprev)
        w = jnp.exp(total[:, None, :] - cum) * dtc         # (b,L,h)
        hnew = (hprev * jnp.exp(total)[:, :, None, None]
                + jnp.einsum("bln,blh,blhd->bhnd", Bc, w, xc))
        return hnew, (y_intra + y_state)

    h0 = jnp.zeros((b, h, n, dh), jnp.float32)
    hfin, y = jax.lax.scan(
        jax.checkpoint(chunk_body), h0,
        (xf.transpose(1, 0, 2, 3, 4), dtf.transpose(1, 0, 2, 3),
         Bf.transpose(1, 0, 2, 3), Cf.transpose(1, 0, 2, 3)))
    y = jnp.moveaxis(y, 0, 1).reshape(b, s, h, dh)
    return y.astype(x.dtype), hfin


def block_forward(slc: Params, x: jax.Array, cfg: ModelConfig,
                  state: Optional[Dict] = None, prefix: str = ""):
    """One Mamba-2 block.  state (decode): {"conv": (B,K-1,C),
    "ssm": (B,H,N,dh)}; None for full-sequence training/prefill."""
    p = {k[len(prefix):]: v for k, v in slc.items()
         if k.startswith(prefix)} if prefix else slc
    h = L.rms_norm(x, p["ln"])
    z = jnp.einsum("bsd,dk->bsk", h, p["in_proj"])
    x_in, gate, B, C, dt = _split_proj(z, cfg)
    conv_in = jnp.concatenate([x_in, B, C], axis=-1)
    conv_out, new_conv = L.causal_conv1d(
        conv_in, p["conv_w"], None if state is None else state["conv"])
    conv_out = jax.nn.silu(conv_out)
    di, ns = cfg.d_inner, cfg.ssm_state
    x_c = conv_out[..., :di]
    B_c = conv_out[..., di:di + ns]
    C_c = conv_out[..., di + ns:]

    nh, dh = cfg.ssm_heads, cfg.ssm_head_dim
    xh = x_c.reshape(x.shape[0], x.shape[1], nh, dh)
    dt_s = jax.nn.softplus(dt.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if state is None:
        y, hfin = ssd_chunked(xh, dt_s, A, B_c, C_c)
    else:
        # recurrent single step: s == 1
        hprev = state["ssm"]
        xt = xh[:, 0].astype(jnp.float32)                  # (b,h,dh)
        dtt = dt_s[:, 0]                                   # (b,h)
        Bt = B_c[:, 0].astype(jnp.float32)                 # (b,n)
        Ct = C_c[:, 0].astype(jnp.float32)
        decay = jnp.exp(A[None] * dtt)[..., None, None]
        hfin = (hprev * decay
                + dtt[..., None, None] * Bt[:, None, :, None]
                * xt[:, :, None, :])
        y = jnp.einsum("bn,bhnd->bhd", Ct, hfin)[:, None]  # (b,1,h,dh)
        y = y.astype(x.dtype)

    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(x.shape[0], x.shape[1], di)
    y = L.rms_norm(y, p["gate_ln"]) * jax.nn.silu(gate)
    out = jnp.einsum("bsd,dk->bsk", y.astype(x.dtype), p["out_proj"])
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv, "ssm": hfin}
    res = hint(x + out, "data", "model", None)  # sequence parallelism
    return res, new_state


def state_shapes(cfg: ModelConfig, batch: int) -> Dict[str, Tuple]:
    return {
        "conv": (cfg.n_layers, batch, cfg.ssm_conv - 1,
                 cfg.d_inner + 2 * cfg.ssm_state),
        "ssm": (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_state,
                cfg.ssm_head_dim),
    }


def init_state(cfg: ModelConfig, batch: int) -> Dict:
    shp = state_shapes(cfg, batch)
    return {"conv": jnp.zeros(shp["conv"], jnp.dtype(cfg.dtype)),
            "ssm": jnp.zeros(shp["ssm"], jnp.float32)}


def state_specs(cfg: ModelConfig, batch: int) -> Dict:
    shp = state_shapes(cfg, batch)
    return {"conv": jax.ShapeDtypeStruct(shp["conv"], jnp.dtype(cfg.dtype)),
            "ssm": jax.ShapeDtypeStruct(shp["ssm"], jnp.float32)}
