"""Pipeline fusion: lower multi-pattern programs as one Pallas kernel.

The paper's programming model composes whole patterns into pipelines
(tpchq6 = filter -> fold, gda = map -> keyed fold, kmeans = assign ->
scatter); its perf claims (Fig. 5/6, the metapipeline overlap of §5)
assume those stages are *vertically fused* so intermediates stay
on-chip.  This module is the subsystem that makes our codegen match
that model: instead of one ``pallas_call`` per pattern with every
intermediate round-tripping HBM, a :class:`Pipeline` lowers as a single
megakernel in which producer tiles land in VMEM scratch (double
buffered per the metapipeline schedule) and are consumed in place --
only pipeline inputs and the final outputs touch main memory.

Structure of a pipeline (a DAG, not just a chain):

  * ``stages`` are *untiled* PPL patterns sharing one 1-D streaming
    domain ``(n,)``; they may be given in any order -- ``validate``
    topologically sorts them and rejects cycles.
  * A stage reads an earlier intermediate as an ``ir.Tensor`` whose
    ``name`` equals the producing stage's ``name`` (a *virtual* tensor:
    it exists in HBM only on the unfused path).  One intermediate may
    feed several consumers (fan-out); every non-output stage must be a
    producer ``Map``.
  * ``outputs`` names the terminal stages.  When omitted it is inferred
    as the stages nothing else consumes.  Terminals may be reductions
    (``MultiFold`` fold / ``GroupByFold``) *or* ``Map``s -- a Map
    terminal lowers through the write-once streaming template (one
    output block per grid step, never revisited).

``fuse_dag`` builds the fused tiled IR: each terminal is strip-mined
onto the shared strided outer and every producer becomes a per-tile
stage via ``fusion.fuse_dag_stages`` -- a fan-out producer is lifted
*exactly once* and its single ``TileCopy`` (stable ``uid``) is shared
by all consumers, so neither its VMEM scratch nor the HBM tiles feeding
it are duplicated.  Each terminal's fused form is ordinary tiled PPL:
``codegen_jax.execute`` is the oracle per terminal,
``memory.plan_memory`` accepts the whole terminal set (shared buffers
counted once), and ``codegen_pallas.lower_fused_dag`` emits the single
multi-output megakernel.

Joint tile-size selection lives in ``dse.explore_pipeline`` (priced on
the fused DAG, per-group block sizes on the split-fallback path, cached
on a topological DAG signature).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from . import ir
from .affine import AffineMap
from .cost import VMEM_BYTES, traffic
from .fusion import fuse_dag_stages, tile_copy_key
from .memory import plan_memory
from .scheduling import Metapipeline, build_schedule
from .strip_mine import insert_tile_copies


@dataclasses.dataclass(frozen=True)
class Pipeline:
    """A DAG of untiled patterns over one shared streaming domain.

    ``outputs=()`` infers the terminal set: every stage no other stage
    consumes.  Chains need no change -- the last stage is the single
    inferred output.

    The fused lowering path (``fuse_dag`` -> ``codegen_pallas.
    lower_fused_pipeline``) runs the whole DAG as one megakernel with
    intermediates in VMEM; ``dse.explore_pipeline`` picks the block
    size and the metapipeline buffer depth jointly (see ``schedule`` /
    ``fused_memory_plan``'s ``depth`` knob) and falls back to
    contiguous topological splits when nothing fits VMEM.
    """

    name: str
    stages: Tuple[ir.Pattern, ...]
    outputs: Tuple[str, ...] = ()

    def __post_init__(self):
        validate(self)

    @property
    def terminal(self) -> ir.Pattern:
        """The single terminal (chains); raises on multi-output DAGs."""
        outs = output_names(self)
        if len(outs) != 1:
            raise ValueError(
                f"pipeline '{self.name}' has {len(outs)} outputs {outs}; "
                "use output_names/terminals")
        return stage_map(self)[outs[0]]

    @property
    def terminals(self) -> Tuple[ir.Pattern, ...]:
        sm = stage_map(self)
        return tuple(sm[n] for n in output_names(self))

    @property
    def shared_extent(self) -> int:
        return self.stages[0].domain[0]

    @property
    def dtype(self) -> str:
        return self.terminals[0].dtype


# --------------------------------------------------------------------------
# DAG structure helpers
# --------------------------------------------------------------------------


def stage_map(pipe: Pipeline) -> Dict[str, ir.Pattern]:
    return {s.name: s for s in pipe.stages}


def _edges(pipe: Pipeline) -> Tuple[Tuple[str, str], ...]:
    """(producer, consumer) name pairs: every read of a stage-named
    Tensor is intermediate wiring."""
    names = {s.name for s in pipe.stages}
    out = []
    for s in pipe.stages:
        for a in s.accesses:
            if isinstance(a.src, ir.Tensor) and a.src.name in names:
                out.append((a.src.name, s.name))
    return tuple(out)


def consumers(pipe: Pipeline) -> Dict[str, Tuple[str, ...]]:
    """Stage name -> names of the stages that read its output."""
    by_prod: Dict[str, List[str]] = {s.name: [] for s in pipe.stages}
    for prod, cons in _edges(pipe):
        if cons not in by_prod[prod]:
            by_prod[prod].append(cons)
    return {k: tuple(v) for k, v in by_prod.items()}


def output_names(pipe: Pipeline) -> Tuple[str, ...]:
    if pipe.outputs:
        return tuple(pipe.outputs)
    cons = consumers(pipe)
    return tuple(s.name for s in topo_stages(pipe) if not cons[s.name])


def topo_stages(pipe: Pipeline) -> Tuple[ir.Pattern, ...]:
    """Stages in canonical topological order (Kahn's algorithm, stage
    name as the deterministic tiebreak so the order -- and therefore the
    DSE cache signature -- is independent of the declaration order).
    Raises ValueError on a dependency cycle."""
    sm = stage_map(pipe)
    indeg = {n: 0 for n in sm}
    succ: Dict[str, List[str]] = {n: [] for n in sm}
    for prod, cons in set(_edges(pipe)):
        indeg[cons] += 1
        succ[prod].append(cons)
    ready = sorted(n for n, d in indeg.items() if d == 0)
    order: List[str] = []
    while ready:
        n = ready.pop(0)
        order.append(n)
        newly = []
        for m in succ[n]:
            indeg[m] -= 1
            if indeg[m] == 0:
                newly.append(m)
        ready = sorted(ready + newly)
    if len(order) != len(sm):
        stuck = sorted(n for n, d in indeg.items() if d > 0)
        raise ValueError(
            f"pipeline '{pipe.name}' has a dependency cycle through "
            f"stages {stuck}")
    return tuple(sm[n] for n in order)


def intermediate_names(pipe: Pipeline) -> Tuple[str, ...]:
    """Non-output stage names, i.e. the virtual tensors produced and
    consumed inside the DAG (topological order)."""
    outs = set(output_names(pipe))
    return tuple(s.name for s in topo_stages(pipe) if s.name not in outs)


def intermediate_words(pipe: Pipeline) -> Dict[str, int]:
    sm = stage_map(pipe)
    return {n: int(np.prod(sm[n].shape)) for n in intermediate_names(pipe)}


def external_inputs(pipe: Pipeline) -> Tuple[ir.Tensor, ...]:
    """Main-memory tensors read by any stage, minus the intermediates."""
    names = {s.name for s in pipe.stages}
    seen: Dict[str, ir.Tensor] = {}
    for s in pipe.stages:
        for t in ir.inputs_of(s):
            if t.name not in names:
                seen.setdefault(t.name, t)
    return tuple(seen.values())


def output_words(pipe: Pipeline) -> int:
    """Total words written to main memory for the pipeline outputs."""
    total = 0
    for t in pipe.terminals:
        total += int(np.prod(t.shape)) if t.shape else 1
    return total


def ragged_extent(pipe: Pipeline) -> Optional[ir.RaggedExtent]:
    """The pipeline's shared ragged extent, or None when every stage
    streams the full static domain (``validate`` already enforced that
    all ragged stages agree)."""
    for s in pipe.stages:
        rag = getattr(s, "ragged", None)
        if rag is not None:
            return rag
    return None


def _is_stream_row_access(a: ir.Access, domain_rank: int) -> bool:
    """True iff the access reads the *current* row along the shared
    streaming domain (base 0, dim 0 advancing 1:1 with the index)."""
    try:
        amap = AffineMap.probe(a.index_map, domain_rank)
    except Exception:
        return False
    if amap.n_out == 0:
        return False
    row_col = (1,) + (0,) * (amap.n_out - 1)
    return amap.base == (0,) * amap.n_out and amap.col(0) == row_col


def validate(pipe: Pipeline) -> None:
    if not pipe.stages:
        raise ValueError("empty pipeline")
    names = set()
    for s in pipe.stages:
        if s.name in names:
            raise ValueError(f"duplicate stage name '{s.name}'")
        names.add(s.name)
    if len(pipe.stages[0].domain) != 1:
        raise ValueError(
            "pipeline stages need a 1-D streaming domain, got "
            f"{pipe.stages[0].domain}")
    (n,) = pipe.stages[0].domain
    for s in pipe.stages:
        if tuple(s.domain) != (n,):
            raise ValueError(
                f"stage '{s.name}' domain {s.domain} != shared ({n},)")
        if s.strided or s.loads:
            raise ValueError(f"stage '{s.name}' must be untiled")

    # ragged streaming domains: every ragged stage must agree on the
    # bound / length scalar / granularity (one live extent per stream),
    # and the static bound must equal the shared domain
    rags = {s.name: s.ragged for s in pipe.stages
            if getattr(s, "ragged", None) is not None}
    if rags:
        uniq = set(rags.values())
        if len(uniq) > 1:
            raise ValueError(
                f"pipeline '{pipe.name}' stages disagree on the ragged "
                f"extent: {sorted(rags)}")
        (rag,) = uniq
        if rag.max != n:
            raise ValueError(
                f"ragged extent max={rag.max} != shared domain ({n},)")
        if n % rag.granularity != 0:
            raise ValueError(
                f"ragged granularity {rag.granularity} must divide the "
                f"shared domain {n}")

    # wiring: reads of stage-named Tensors must match the producer's
    # realized shape exactly (fan-out into a differently-shaped view
    # would silently read garbage on the fused path)
    sm = stage_map(pipe)
    for s in pipe.stages:
        for a in s.accesses:
            if isinstance(a.src, ir.Tensor) and a.src.name in names:
                prod = sm[a.src.name]
                if tuple(a.src.shape) != tuple(prod.shape):
                    raise ValueError(
                        f"stage '{s.name}' reads intermediate "
                        f"'{a.src.name}' with mismatched extents "
                        f"{tuple(a.src.shape)}; stage '{prod.name}' "
                        f"produces {tuple(prod.shape)}")

    # explicit outputs must name stages
    for o in pipe.outputs:
        if o not in names:
            raise ValueError(
                f"pipeline '{pipe.name}' output '{o}' names no stage")

    topo = topo_stages(pipe)  # raises on cycles
    cons = consumers(pipe)
    outs = output_names(pipe)
    if pipe.outputs:
        for s in topo:
            if s.name not in set(outs) and not cons[s.name]:
                raise ValueError(
                    f"dangling intermediate '{s.name}': produced but "
                    "never consumed and not a pipeline output")
        for o in outs:
            if cons[o]:
                raise NotImplementedError(
                    f"output stage '{o}' is also consumed by "
                    f"{list(cons[o])}; a stage cannot be both a "
                    "terminal and an intermediate")

    # producers (non-terminal stages) must be Maps
    for s in topo:
        if s.name not in set(outs) and not isinstance(s, ir.Map):
            raise NotImplementedError(
                f"producer stage '{s.name}' must be a Map")

    # a Map terminal streams one write-once output block per grid step;
    # a non-current-row read of an intermediate would force the outer to
    # revisit earlier tiles, which the template cannot do
    for o in outs:
        t = sm[o]
        if not isinstance(t, ir.Map):
            continue
        for a in t.accesses:
            if isinstance(a.src, ir.Tensor) and a.src.name in names \
                    and not _is_stream_row_access(a, 1):
                raise ValueError(
                    f"Map terminal '{t.name}' would need a revisited "
                    f"outer: its read of intermediate '{a.src.name}' is "
                    "not the current streamed row")


# --------------------------------------------------------------------------
# Fused IR
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FusedDag:
    """The fused form of a pipeline DAG at one streaming tile size.

    ``terminals`` pairs each output name with its fused tiled pattern
    (a 1-D strided outer whose producer stages are pattern-valued
    TileCopies).  The per-terminal patterns *share* producer TileCopies
    by ``uid`` -- that sharing is the fan-out contract: one VMEM
    scratch buffer and one set of HBM feeds per producer, regardless of
    how many consumers it has.  ``refcounts`` records the consumer
    count per producer stage.
    """

    name: str
    block: int
    grid: int
    terminals: Tuple[Tuple[str, ir.Pattern], ...]
    refcounts: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def patterns(self) -> Tuple[ir.Pattern, ...]:
        return tuple(p for _, p in self.terminals)


def fuse_dag(pipe: Pipeline, block: int, *,
             vmem_budget_words: int = VMEM_BYTES // 4) -> FusedDag:
    """The whole DAG as per-terminal tiled patterns sharing producer
    stages: producers are VMEM-resident per-tile stages (one TileCopy
    per producer, ref-counted across consumers), and only external
    tensors get (HBM -> VMEM) tile copies."""
    topo = topo_stages(pipe)
    outs = output_names(pipe)
    fused_by_name = fuse_dag_stages(topo, outs, block)
    terminals = []
    for o in outs:
        t = insert_tile_copies(fused_by_name[o],
                               vmem_budget_words=vmem_budget_words)
        terminals.append((o, t))
    cons = consumers(pipe)
    refcounts = {n: len(cons[n]) for n in intermediate_names(pipe)}
    return FusedDag(name=pipe.name, block=block,
                    grid=pipe.shared_extent // block,
                    terminals=tuple(terminals), refcounts=refcounts)


def fuse(pipe: Pipeline, block: int, *,
         vmem_budget_words: int = VMEM_BYTES // 4) -> ir.Pattern:
    """Single-output convenience: the fused DAG's one terminal pattern
    (back-compat with the PR-2 chain API)."""
    fdag = fuse_dag(pipe, block, vmem_budget_words=vmem_budget_words)
    if len(fdag.terminals) != 1:
        raise ValueError(
            f"pipeline '{pipe.name}' has multiple outputs "
            f"{output_names(pipe)}; use fuse_dag")
    return fdag.terminals[0][1]


def schedule(pipe: Pipeline, block: int, *,
             vmem_budget_words: int = VMEM_BYTES // 4,
             depth: int = 2) -> Optional[Metapipeline]:
    """Metapipeline schedule of the fused kernel (the first terminal's
    tree -- producer stages and boundary-crossing loads all buffered at
    ``depth`` rotating copies, 2 = classic double buffer; shared stages
    appear identically in every terminal's schedule).
    ``dse.explore_pipeline`` searches ``depth`` jointly with the block
    size and records the choice in ``PipelinePlan.depths``."""
    fdag = fuse_dag(pipe, block, vmem_budget_words=vmem_budget_words)
    return build_schedule(fdag.terminals[0][1], vmem_budget_words,
                          depth=depth)


# --------------------------------------------------------------------------
# Reference execution (unfused path + oracle)
# --------------------------------------------------------------------------


def _as_output(pipe: Pipeline, env: Dict[str, Any]):
    outs = output_names(pipe)
    if len(outs) == 1:
        return env[outs[0]]
    return {n: env[n] for n in outs}


def run_unfused(pipe: Pipeline, inputs: Dict[str, Any],
                *, return_intermediates: bool = False):
    """Execute stage-by-stage (topological order) through the
    ``codegen_jax`` oracle, materializing every intermediate (the
    pre-fusion lowering: one kernel per pattern, intermediates
    round-trip HBM).  Multi-output DAGs return a name -> array dict."""
    from .codegen_jax import execute  # local import: avoid cycle

    env = dict(inputs)
    for s in topo_stages(pipe):
        env[s.name] = execute(s, env)
    out = _as_output(pipe, env)
    if return_intermediates:
        return out, {k: env[k] for k in intermediate_names(pipe)}
    return out


def unfused_runner(pipe: Pipeline) -> Callable:
    """A jitted closure over the unfused stage DAG (inputs as kwargs)."""
    import jax

    @jax.jit
    def run(**inputs):
        return run_unfused(pipe, inputs)

    return run


# --------------------------------------------------------------------------
# Traffic accounting (the quantity joint DSE minimizes)
# --------------------------------------------------------------------------


def unfused_traffic_words(pipe: Pipeline) -> int:
    """Total HBM words moved by the per-pattern lowering: every stage's
    main-memory reads (intermediates included -- they are real tensors
    on this path, and a fan-out intermediate is read once per consumer)
    plus every intermediate write plus the output writes."""
    words = 0
    for s in pipe.stages:
        words += traffic(s).total_reads
    words += sum(intermediate_words(pipe).values())
    words += output_words(pipe)
    return int(words)


def dag_external_reads(fdag: FusedDag) -> Dict[str, int]:
    """HBM words read per external tensor by the fused megakernel.

    Every tensor tile copy hangs off the shared 1-D strided outer, so a
    non-hoisted copy streams once per grid step and a hoisted copy is
    the Pipe-0 preload (loaded once).  Copies are deduplicated across
    terminals by ``fusion.tile_copy_key`` -- the kernel issues one DMA
    per distinct (tensor, index map, tile) regardless of how many
    terminal trees reference it -- and producer stages contribute
    nothing (they are VMEM-resident).
    """
    reads: Dict[str, int] = {}
    seen = set()
    for _, t in fdag.terminals:
        tree_tc: Dict[str, int] = {}   # this tree's copy words, undeduped
        streamed = set()
        for node in ir.walk(t):
            for tc in node.loads:
                if not isinstance(tc.src, ir.Tensor):
                    continue
                trips = 1 if tc.hoisted else fdag.grid
                words = trips * tc.words // tc.reuse
                tree_tc[tc.src.name] = (tree_tc.get(tc.src.name, 0)
                                        + words)
                key = tile_copy_key(tc)
                if key in seen:
                    continue
                seen.add(key)
                reads[tc.src.name] = reads.get(tc.src.name, 0) + words
            for a in node.accesses:
                if isinstance(a.src, ir.Tensor) and a.affine:
                    streamed.add(a.src.name)
        if streamed:
            # direct affine tensor reads left in place are the
            # streaming fallback (tile too big for VMEM): charge, once
            # per tree, whatever cost.traffic attributes to the tensor
            # beyond its tile copies (no cross-terminal CSE exists for
            # streamed reads)
            tr = traffic(t)
            for name in streamed:
                extra = tr.reads.get(name, 0) - tree_tc.get(name, 0)
                reads[name] = reads.get(name, 0) + max(extra, 0)
    return reads


def fused_traffic_words(pipe: Pipeline, block: int, *,
                        vmem_budget_words: int = VMEM_BYTES // 4) -> int:
    """Total HBM words moved by the fused megakernel: external reads of
    the fused DAG (intermediates are VMEM-resident, contributing zero;
    fan-out tiles counted once) plus the output writes."""
    fdag = fuse_dag(pipe, block, vmem_budget_words=vmem_budget_words)
    return int(sum(dag_external_reads(fdag).values())) + output_words(pipe)


def fused_memory_plan(pipe: Pipeline, block: int, *,
                      vmem_budget_bytes: int = VMEM_BYTES,
                      depth: int = 2):
    """VMEM plan of the fused kernel across the whole terminal set
    (stage scratch charged at ``depth`` rotating copies -- 2 = classic
    double buffer -- so deeper buffering competes with bigger tiles
    under the budget; fan-out scratch counted once)."""
    fdag = fuse_dag(pipe, block,
                    vmem_budget_words=vmem_budget_bytes // 4)
    return plan_memory(fdag.patterns, vmem_budget_bytes=vmem_budget_bytes,
                       depth=depth)


# --------------------------------------------------------------------------
# Split-fallback support: contiguous topological sub-pipelines
# --------------------------------------------------------------------------


def sub_pipeline(pipe: Pipeline, i0: int, i1: int) -> Pipeline:
    """Stages ``topo[i0:i1]`` as their own pipeline.  Its outputs are
    the range's pipeline outputs plus every stage consumed outside the
    range (those intermediates round-trip HBM at the group boundary)."""
    topo = topo_stages(pipe)
    chosen = topo[i0:i1]
    inside = {s.name for s in chosen}
    pipe_outs = set(output_names(pipe))
    cons = consumers(pipe)
    outs = tuple(s.name for s in chosen
                 if s.name in pipe_outs
                 or any(c not in inside for c in cons[s.name]))
    return Pipeline(name=f"{pipe.name}:{chosen[0].name}",
                    stages=chosen, outputs=outs)


# --------------------------------------------------------------------------
# Lowering front-end (the `fused=True` path)
# --------------------------------------------------------------------------


def lower_pipeline(pipe: Pipeline, *, fused: bool = True, plan=None,
                   vmem_budget: Optional[int] = None,
                   cache=None, measure=None, policy=None) -> Callable:
    """Lower a pipeline to an executable callable.

    ``fused=True`` (default) runs joint DSE and emits the single-kernel
    Pallas lowering (``codegen_pallas.lower_fused_pipeline``);
    ``fused=False`` returns the per-stage oracle DAG -- the pre-fusion
    semantics every fused kernel is validated against.  Multi-output
    pipelines return a name -> array dict either way.  ``measure`` and
    ``policy`` (a ``resilience.Policy``) pass through to the joint DSE:
    measured mode, per-candidate deadlines, quarantine, certification.
    """
    if not fused:
        return unfused_runner(pipe)
    from .codegen_pallas import lower_fused_pipeline
    return lower_fused_pipeline(pipe, plan=plan, vmem_budget=vmem_budget,
                                cache=cache, measure=measure,
                                policy=policy)
