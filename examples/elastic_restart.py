"""Fault-tolerance drill: train, checkpoint, 'lose' a node, rescale,
restore onto the new mesh plan, and keep training with identical data.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import tempfile

from repro.checkpoint import manager as ckpt
from repro.launch.train import train
from repro.runtime.fault_tolerance import (HeartbeatMonitor,
                                           StragglerPolicy, plan_rescale)

with tempfile.TemporaryDirectory() as d:
    print("== phase 1: train 10 steps, checkpoint every 5 ==")
    train("granite-3-2b", smoke=True, n_steps=10, batch=2, seq=32,
          ckpt_dir=d, ckpt_every=5)

    print("\n== phase 2: heartbeat monitor declares a node dead ==")
    mon = HeartbeatMonitor([f"node{i}" for i in range(16)], timeout_s=30)
    for n in list(mon.nodes)[:-1]:
        mon.heartbeat(n, now=1000.0)
    mon.nodes["node15"].last_heartbeat = 900.0
    dead = mon.sweep(now=1000.0)
    print("dead:", dead, "| survivors:", len(mon.alive()))

    print("\n== phase 3: rescale plan from survivors ==")
    plan = plan_rescale(15 * 16, model_parallel=16)
    print(f"new mesh: data={plan.data} x model={plan.model} "
          f"(dropped {plan.dropped})")

    print("\n== phase 4: straggler policy ==")
    pol = StragglerPolicy()
    for _ in range(4):
        d_ = {f"r{i}": 1.0 for i in range(8)}
        d_["r5"] = 2.5
        evict = pol.record_step(d_)
    print("evict:", evict)

    print("\n== phase 5: restart resumes from checkpoint ==")
    losses, _ = train("granite-3-2b", smoke=True, n_steps=14, batch=2,
                      seq=32, ckpt_dir=d, ckpt_every=5)
    print(f"resumed and ran {len(losses)} more steps; final loss "
          f"{losses[-1]:.3f}")
print("OK")
