"""Serving path: one-call block prefill == token-by-token oracle, and
the mixed-prompt-length driver preserving request order.

``steps.make_cache_prefill_step`` runs attention families as a single
block ``decode_step`` and recurrent families as an in-jit token scan;
either way the resulting cache and next token must match feeding the
prompt one token at a time (the pre-ISSUE-8 serve loop).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import serve, steps
from repro.models import model


def _greedy(logits, cfg):
    logits = model.mask_vocab_pad(logits, cfg)
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["granite-3-2b",   # dense: block path
                                  "mamba2-370m"])   # ssm: scan path
def test_cache_prefill_matches_token_by_token(arch):
    cfg = get_config(arch, smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    B, S, room = 2, 8, 4
    rng = np.random.RandomState(1)
    prompt = jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32)

    prefill = jax.jit(steps.make_cache_prefill_step(cfg))
    nxt_a, cache_a = prefill(params, model.init_cache(cfg, B, S + room),
                             prompt, jnp.int32(0))

    cache_b = model.init_cache(cfg, B, S + room)
    for i in range(S):
        logits, cache_b = model.decode_step(params, cfg, cache_b,
                                            prompt[:, i:i + 1],
                                            jnp.int32(i))
    np.testing.assert_array_equal(np.asarray(nxt_a),
                                  np.asarray(_greedy(logits, cfg)))
    for a, b in zip(jax.tree_util.tree_leaves(cache_a),
                    jax.tree_util.tree_leaves(cache_b)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-4)


def test_prefill_chunks_at_ring_boundary():
    """A prompt longer than the KV ring serves through ``_prefill``'s
    chunking (a block write must not wrap the ring)."""
    cfg = get_config("granite-3-2b", smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    B, total = 1, 12
    ring = serve._ring_len(cfg, total)
    S = ring + 3 if ring < total else total   # force >= 2 chunks if we can
    rng = np.random.RandomState(2)
    prompt = jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32)

    prefill = jax.jit(steps.make_cache_prefill_step(cfg))
    nxt_a, _ = serve._prefill(prefill, params,
                              model.init_cache(cfg, B, total),
                              prompt, ring)

    cache_b = model.init_cache(cfg, B, total)
    for i in range(S):
        logits, cache_b = model.decode_step(params, cfg, cache_b,
                                            prompt[:, i:i + 1],
                                            jnp.int32(i))
    np.testing.assert_array_equal(np.asarray(nxt_a),
                                  np.asarray(_greedy(logits, cfg)))


def test_serve_mixed_prompt_lengths_preserve_order():
    """Requests re-grouped by prompt length come back in input order:
    the rows sharing the uniform run's length generate identical
    tokens, regardless of which group they decoded in."""
    uniform = serve.serve("granite-3-2b", True, 3, 6, 2)
    mixed = serve.serve("granite-3-2b", True, 3, 6, 2,
                        prompt_lens=(6, 4, 6))
    assert mixed.shape == (3, 2)
    np.testing.assert_array_equal(mixed[[0, 2]], uniform[[0, 2]])


def test_resolve_group_plans_use_per_group_extent(monkeypatch):
    """Regression (ISSUE 9): each prompt-length group's attention plan
    resolves at ITS OWN KV extent ``ln + gen``, not the global
    ``max(lens) + gen`` every group used to be priced at."""
    from repro.kernels import ops

    cfg = get_config("granite-3-2b", smoke=True)
    calls = []

    def fake_resolve(kind, *shape, **kw):
        calls.append((kind, shape))

        class P:
            warm_start, bucket, cached, sizes = False, "", False, {}
        return (None, P())

    monkeypatch.setattr(ops, "resolve_plan", fake_resolve)
    serve._resolve_group_plans(cfg, [4, 6], gen=2)
    hd = cfg.head_dim or (cfg.d_model // max(cfg.n_heads, 1))
    assert calls == [("attention", (4, 6, hd)),
                     ("attention", (6, 8, hd))]


def test_zero_length_prompts_rejected():
    """Regression (ISSUE 9): a zero-length prompt must fail loudly at
    validation, not prefill garbage."""
    with pytest.raises(ValueError, match="positive"):
        serve.serve("granite-3-2b", True, 3, 6, 2,
                    prompt_lens=(6, 0, 6))
    cfg = get_config("granite-3-2b", smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prefill = jax.jit(steps.make_cache_prefill_step(cfg))
    empty = jnp.zeros((1, 0), jnp.int32)
    with pytest.raises(ValueError, match="zero-length"):
        serve._prefill(prefill, params, model.init_cache(cfg, 1, 4),
                       empty, 4)


def test_serve_continuous_matches_oracle_per_request():
    """Continuous batching over the paged pool (admit/evict churn,
    more requests than slots, fused Pallas decode, certification on)
    returns every request's tokens in input order, token-identical to
    a per-request dense-cache oracle decode."""
    lens, gen, slots = (3, 5, 9, 4), 3, 2
    toks, stats = serve.serve_continuous("granite-3-2b", True, slots,
                                         gen, prompt_lens=lens)
    assert toks.shape == (len(lens), gen)
    assert stats["certified"] is True and stats["use_pallas"]
    assert stats["admitted"] == stats["evicted"] == len(lens)
    assert 0 < stats["occupancy"] <= 1
    assert 0 < stats["modeled_paged_traffic_words"] \
        < stats["modeled_dense_traffic_words"]

    cfg = get_config("granite-3-2b", smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    pool = rng.randint(0, cfg.vocab, (len(lens), max(lens)))
    cmax = -(-(max(lens) + gen) // stats["page_size"]) \
        * stats["page_size"]
    step = jax.jit(steps.make_serve_step(cfg))
    for r, ln in enumerate(lens):
        cache = model.init_cache(cfg, 1, cmax)
        nxt, want = None, []
        for i in range(ln + gen):
            tok = (pool[r:r + 1, i:i + 1] if i < ln
                   else np.asarray(nxt).reshape(1, 1))
            nxt, cache = step(params, cache,
                              jnp.asarray(tok, jnp.int32), jnp.int32(i))
            if i >= ln:
                want.append(int(np.asarray(nxt)[0]))
        assert list(toks[r]) == want, f"request {r} diverged"
