"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the mathematical definition, written for clarity and
numerical trustworthiness, not speed.  Kernel tests sweep shapes/dtypes
and assert allclose against these.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32))


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True,
              window: Optional[int] = None,
              scale: Optional[float] = None) -> jax.Array:
    """Grouped-query attention oracle.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); Hq % Hkv == 0.
    ``window`` = sliding-window size (Mistral/Mixtral SWA): query i
    attends to keys in (i - window, i].
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kq.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # decode offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs,
                      vq.astype(jnp.float32)).astype(q.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array) -> jax.Array:
    """Mamba-2 SSD (state-space duality) oracle -- sequential recurrence.

    x:  (batch, seq, heads, head_dim)
    dt: (batch, seq, heads)        positive step sizes
    A:  (heads,)                   negative decay rates
    B:  (batch, seq, state)        input projection (shared across heads)
    C:  (batch, seq, state)        output projection
    Returns y: (batch, seq, heads, head_dim).

    h_t = exp(A dt_t) h_{t-1} + dt_t * B_t x_t^T ;  y_t = C_t^T h_t
    """
    bsz, seq, h, dh = x.shape
    n = B.shape[-1]

    def step(hstate, inp):
        xt, dtt, Bt, Ct = inp  # (h,dh), (h,), (n,), (n,)
        decay = jnp.exp(A * dtt)[:, None, None]            # (h,1,1)
        hstate = hstate * decay + (dtt[:, None, None]
                                   * Bt[None, :, None]
                                   * xt[:, None, :])        # (h,n,dh)
        yt = jnp.einsum("n,hnd->hd", Ct, hstate)
        return hstate, yt

    def per_batch(xb, dtb, Bb, Cb):
        h0 = jnp.zeros((h, n, dh), jnp.float32)
        _, y = jax.lax.scan(step, h0,
                            (xb.astype(jnp.float32),
                             dtb.astype(jnp.float32),
                             Bb.astype(jnp.float32),
                             Cb.astype(jnp.float32)))
        return y

    y = jax.vmap(per_batch)(x, dt, B, C)
    return y.astype(x.dtype)


def groupby_fold(keys: jax.Array, values: jax.Array,
                 num_keys: int) -> jax.Array:
    """Dense keyed sum: out[k] = sum of values[i] with keys[i] == k."""
    onehot = jax.nn.one_hot(keys, num_keys, dtype=jnp.float32)
    return jnp.einsum("ik,i...->k...", onehot,
                      values.astype(jnp.float32))


def filter_reduce(x: jax.Array, lo: jax.Array, hi: jax.Array,
                  weight: jax.Array) -> jax.Array:
    """TPC-H Q6 shape: sum(weight[i] * x[i]) over lo <= x[i] < hi."""
    pred = (x >= lo) & (x < hi)
    return jnp.sum(jnp.where(pred, x.astype(jnp.float32)
                             * weight.astype(jnp.float32), 0.0))
