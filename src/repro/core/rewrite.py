"""Generic pattern-tree rewriting utilities.

Transformations need to (a) rebuild frozen pattern nodes with changed
fields and (b) re-wrap every index-sensitive callable in a subtree when
the enclosing index stack changes shape (strip mining inserts grid+local
index pairs; interchange permutes stack segments).

A *stack transform* is a function ``new_stack -> old_stack`` mapping the
indices a callable will now receive to the indices it was written
against.  ``rewrap`` applies one to every callable in a subtree.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

from . import ir

StackXform = Callable[[Tuple], Tuple]


def compose(f: StackXform, g: StackXform) -> StackXform:
    return lambda s: g(f(s))


def wrap_index_map(index_map: Callable, xform: StackXform) -> Callable:
    def wrapped(*stack):
        return index_map(*xform(tuple(stack)))

    return wrapped


def wrap_body_fn(fn: Callable, xform: StackXform) -> Callable:
    """Body fns take the stack as their first (tuple) argument."""

    def wrapped(stack, *rest):
        return fn(xform(tuple(stack)), *rest)

    return wrapped


def _rewrap_access(a: ir.Access, xform: StackXform) -> ir.Access:
    src = a.src
    if isinstance(src, ir.Pattern):
        src = rewrap(src, xform)
    return dataclasses.replace(
        a, src=src, index_map=wrap_index_map(a.index_map, xform))


def _rewrap_tilecopy(tc: ir.TileCopy, xform: StackXform) -> ir.TileCopy:
    src = tc.src
    if isinstance(src, ir.Pattern):
        src = rewrap(src, xform)
    return dataclasses.replace(
        tc, src=src, index_map=wrap_index_map(tc.index_map, xform))


def rewrap(p: ir.Pattern, xform: StackXform) -> ir.Pattern:
    """Re-wrap every callable in the subtree rooted at ``p`` so that it
    translates the *new* incoming stack back to the stack layout it was
    originally written against.  The transform applies uniformly to the
    whole subtree because enclosing indices are a prefix of every nested
    stack: ``xform`` must preserve any suffix beyond the region it edits
    (our xforms operate on a fixed prefix and pass the tail through).
    """
    updates = {}
    updates["reads"] = tuple(_rewrap_access(a, xform) for a in p.accesses)
    updates["tile_loads"] = tuple(
        _rewrap_tilecopy(t, xform) for t in p.loads)
    if p.fn is not None:
        updates["fn"] = wrap_body_fn(p.fn, xform)
    if isinstance(p, ir.MultiFold) and p.out_index_map is not None:
        updates["out_index_map"] = wrap_index_map(p.out_index_map, xform)
    if p.inner is not None:
        updates["inner"] = rewrap(p.inner, xform)
    return dataclasses.replace(p, **updates)


def prefix_preserving_tail(edit: Callable[[Tuple], Tuple],
                           edit_len: int) -> StackXform:
    """Build a StackXform that applies ``edit`` to the first ``edit_len``
    entries of the stack and passes any remaining (deeper-nested) indices
    through unchanged."""

    def xform(stack: Tuple) -> Tuple:
        head, tail = tuple(stack[:edit_len]), tuple(stack[edit_len:])
        return tuple(edit(head)) + tail

    return xform


def map_tree(p: ir.Pattern, fn: Callable[[ir.Pattern], Optional[ir.Pattern]]
             ) -> ir.Pattern:
    """Bottom-up rebuild: ``fn`` may return a replacement for each node."""
    updates = {}
    if p.inner is not None:
        updates["inner"] = map_tree(p.inner, fn)
    new_reads = []
    changed = False
    for a in p.accesses:
        if isinstance(a.src, ir.Pattern):
            new_src = map_tree(a.src, fn)
            if new_src is not a.src:
                a = dataclasses.replace(a, src=new_src)
                changed = True
        new_reads.append(a)
    if changed:
        updates["reads"] = tuple(new_reads)
    if updates:
        p = dataclasses.replace(p, **updates)
    out = fn(p)
    return out if out is not None else p
