"""The paper's benchmark suite (Table 5) as PPL programs.

Each builder returns ``(pattern, tile_sizes, make_inputs, reference)``:
the untransformed pattern is the *base* configuration; ``tile_sizes``
feed ``repro.core.tile`` for the tiled/metapipelined configurations.

  outerprod   vector outer product          (map)
  sumrows     matrix row summation          (map, reduce)
  gemm        matrix multiplication         (map, reduce)
  tpchq6      filtered weighted sum         (filter, reduce -- fused)
  gda         class-wise scatter moments    (map, filter, reduce)
  kmeans      k-means clustering step       (map, groupBy, reduce)
"""
from __future__ import annotations


import jax.numpy as jnp
import numpy as np

from repro.core import ir


def _rng(seed, *shape):
    return np.random.RandomState(seed).randn(*shape).astype(np.float32)


# ------------------------------------------------------------- outerprod
def outerprod(m=256, n=256, bm=64, bn=64):
    x = ir.Tensor("x", (m,))
    y = ir.Tensor("y", (n,))
    p = ir.Map(
        domain=(m, n),
        reads=(ir.Access(x, lambda i, j: (i,), (1,)),
               ir.Access(y, lambda i, j: (j,), (1,))),
        fn=lambda s, xe, ye: xe * ye, name="outer")
    sizes = {"outer": (bm, bn)}

    def make_inputs():
        return {"x": _rng(0, m), "y": _rng(1, n)}

    def reference(inp):
        return np.outer(inp["x"], inp["y"])

    return p, sizes, make_inputs, reference


# --------------------------------------------------------------- sumrows
def sumrows(m=256, n=256, b0=64, b1=64):
    x = ir.Tensor("x", (m, n))
    p = ir.MultiFold(
        domain=(m, n), range_shape=(m,),
        init=lambda: jnp.zeros((m,)),
        reads=(ir.elem(x),),
        out_index_map=lambda i, j: (i,), update_shape=(1,),
        fn=lambda s, acc, e: acc + e,
        combine=lambda a, b: a + b, name="sumrows")
    sizes = {"sumrows": (b0, b1)}

    def make_inputs():
        return {"x": _rng(2, m, n)}

    def reference(inp):
        return inp["x"].sum(1)

    return p, sizes, make_inputs, reference


# ------------------------------------------------------------------ gemm
def gemm(m=128, n=128, k=128, bm=64, bn=64, bk=64):
    x = ir.Tensor("x", (m, k))
    y = ir.Tensor("y", (k, n))
    kfold = ir.MultiFold(
        domain=(k,), range_shape=(), init=lambda: jnp.zeros(()),
        reads=(ir.Access(x, lambda i, j, kk: (i, kk), (1, 1)),
               ir.Access(y, lambda i, j, kk: (kk, j), (1, 1))),
        out_index_map=lambda i, j, kk: (), update_shape=(),
        fn=lambda s, acc, xe, ye: acc + xe * ye,
        combine=lambda a, b: a + b, name="gemm_k")
    p = ir.Map(domain=(m, n), inner=kfold, name="gemm")
    sizes = {"gemm": (bm, bn), "gemm_k": (bk,)}

    def make_inputs():
        return {"x": _rng(3, m, k), "y": _rng(4, k, n)}

    def reference(inp):
        return inp["x"] @ inp["y"]

    return p, sizes, make_inputs, reference


# ---------------------------------------------------------------- tpchq6
def tpchq6(n=4096, b=512):
    """SELECT sum(price * discount) WHERE lo <= qty < hi -- the filter
    fuses into the fold (the FPGA FIFO disappears; DESIGN.md §2)."""
    qty = ir.Tensor("qty", (n,))
    price = ir.Tensor("price", (n,))
    disc = ir.Tensor("disc", (n,))
    lo, hi = 0.05, 0.95

    def fn(s, acc, q, pr, dc):
        pred = (q >= lo) & (q < hi)
        return acc + jnp.where(pred, pr * dc, 0.0)

    p = ir.MultiFold(
        domain=(n,), range_shape=(), init=lambda: jnp.zeros(()),
        reads=(ir.elem(qty), ir.elem(price), ir.elem(disc)),
        out_index_map=lambda i: (), update_shape=(),
        fn=fn, combine=lambda a, b: a + b, name="q6")
    sizes = {"q6": (b,)}

    def make_inputs():
        r = np.random.RandomState(5)
        return {"qty": r.rand(n).astype(np.float32),
                "price": r.rand(n).astype(np.float32),
                "disc": r.rand(n).astype(np.float32)}

    def reference(inp):
        pred = (inp["qty"] >= lo) & (inp["qty"] < hi)
        return np.sum(np.where(pred, inp["price"] * inp["disc"], 0.0))

    return p, sizes, make_inputs, reference


# ------------------------------------------------------------------- gda
def gda(n=512, d=8, k=4, b0=64):
    """Per-class scatter moments: sum_k [x_i ; x_i x_i^T] over class k --
    map + groupBy + reduce (the paper's GDA core)."""
    pts = ir.Tensor("pts", (n, d))
    labels = ir.Tensor("labels", (n,))
    ew = d + d * d

    def fn(s, lab, row):
        key = lab.astype(jnp.int32)
        outer = jnp.outer(row, row).reshape(d * d)
        return key, jnp.concatenate([row, outer])

    p = ir.GroupByFold(
        domain=(n,), num_keys=k, elem_shape=(ew,),
        init=lambda: jnp.zeros((k, ew)),
        reads=(ir.elem(labels),
               ir.Access(pts, lambda i: (i, 0), (1, d))),
        fn=fn, combine=lambda a, b: a + b, name="gda")
    sizes = {"gda": (b0,)}

    def make_inputs():
        r = np.random.RandomState(6)
        return {"pts": r.randn(n, d).astype(np.float32),
                "labels": r.randint(0, k, n).astype(np.float32)}

    def reference(inp):
        out = np.zeros((k, ew), np.float32)
        for i in range(n):
            c = int(inp["labels"][i])
            row = inp["pts"][i]
            out[c, :d] += row
            out[c, d:] += np.outer(row, row).reshape(-1)
        return out

    return p, sizes, make_inputs, reference


# ---------------------------------------------------------------- kmeans
def kmeans(n=256, k=8, d=16, b0=32, b1=4):
    pts = ir.Tensor("points", (n, d))
    cents = ir.Tensor("centroids", (k, d))

    assign = ir.MultiFold(
        domain=(k,), range_shape=(2,),
        init=lambda: jnp.array([jnp.inf, -1.0]),
        reads=(ir.Access(cents, lambda i, j: (j, 0), (1, d)),
               ir.Access(pts, lambda i, j: (i, 0), (1, d))),
        out_index_map=lambda i, j: (0,), update_shape=(2,),
        fn=lambda s, acc, c_row, p_row: jnp.where(
            jnp.sum((p_row - c_row) ** 2) < acc[..., 0],
            jnp.stack([jnp.sum((p_row - c_row) ** 2),
                       jnp.float32(s[-1])]), acc),
        combine=lambda a, b: jnp.where(a[..., :1] <= b[..., :1], a, b),
        name="assign")

    def scatter_fn(s, pair, p_row):
        return pair[1].astype(jnp.int32), jnp.concatenate(
            [p_row, jnp.ones((1,))])

    p = ir.GroupByFold(
        domain=(n,), num_keys=k, elem_shape=(d + 1,),
        init=lambda: jnp.zeros((k, d + 1)),
        reads=(ir.Access(assign, lambda i: (0,), (2,)),
               ir.Access(pts, lambda i: (i, 0), (1, d))),
        fn=scatter_fn, combine=lambda a, b: a + b, name="scatter")
    sizes = {"scatter": (b0,), "assign": (b1,)}

    def make_inputs():
        return {"points": _rng(7, n, d), "centroids": _rng(8, k, d)}

    def reference(inp):
        pts_, cents_ = inp["points"], inp["centroids"]
        d2 = ((pts_[:, None] - cents_[None]) ** 2).sum(-1)
        idx = d2.argmin(1)
        out = np.zeros((k, d + 1), np.float32)
        for i in range(n):
            out[idx[i], :d] += pts_[i]
            out[idx[i], d] += 1
        return out

    return p, sizes, make_inputs, reference


SUITE = {
    "outerprod": outerprod,
    "sumrows": sumrows,
    "gemm": gemm,
    "tpchq6": tpchq6,
    "gda": gda,
    "kmeans": kmeans,
}


# ==========================================================================
# Pipelines: the same benchmarks in the paper's *composed* form -- a DAG
# of whole patterns wired through named intermediates.  These are the
# programs pipeline fusion lowers as single megakernels (the ``fused=True``
# path via ``core.pipeline.lower_pipeline``); unfused, every intermediate
# round-trips HBM, which is exactly the traffic the fused lowering deletes.
# Each builder returns ``(Pipeline, make_inputs, reference)``; for
# multi-output DAGs ``reference`` returns a name -> array dict matching
# ``core.pipeline.output_names``.
# ==========================================================================


def tpchq6_pipeline(n=4096):
    """tpchq6 as filter -> fold: a mask Map producing the per-record
    contribution (the (n,) intermediate), summed by a separate fold."""
    from repro.core.pipeline import Pipeline

    qty = ir.Tensor("qty", (n,))
    price = ir.Tensor("price", (n,))
    disc = ir.Tensor("disc", (n,))
    lo, hi = 0.05, 0.95

    mask = ir.Map(
        domain=(n,),
        reads=(ir.elem(qty), ir.elem(price), ir.elem(disc)),
        fn=lambda s, q, pr, dc: jnp.where((q >= lo) & (q < hi),
                                          pr * dc, 0.0),
        name="q6_mask")
    total = ir.MultiFold(
        domain=(n,), range_shape=(), init=lambda: jnp.zeros(()),
        reads=(ir.elem(ir.Tensor("q6_mask", (n,))),),
        out_index_map=lambda i: (), update_shape=(),
        fn=lambda s, acc, v: acc + v,
        combine=lambda a, b: a + b, name="q6_sum")

    _, _, make_inputs, reference = tpchq6(n)
    return Pipeline(name="tpchq6", stages=(mask, total)), \
        make_inputs, reference


def gda_pipeline(n=512, d=8, k=4):
    """gda as map -> keyed fold: a feature Map producing [x ; x x^T] per
    point (the (n, d + d*d) intermediate), scattered per class."""
    from repro.core.pipeline import Pipeline

    pts = ir.Tensor("pts", (n, d))
    labels = ir.Tensor("labels", (n,))
    ew = d + d * d

    def feat_fn(s, row):
        return jnp.concatenate([row, jnp.outer(row, row).reshape(d * d)])

    feat = ir.Map(
        domain=(n,), elem_shape=(ew,),
        reads=(ir.Access(pts, lambda i: (i, 0), (1, d)),),
        fn=feat_fn, name="gda_feat")
    scatter = ir.GroupByFold(
        domain=(n,), num_keys=k, elem_shape=(ew,),
        init=lambda: jnp.zeros((k, ew)),
        reads=(ir.elem(labels),
               ir.Access(ir.Tensor("gda_feat", (n, ew)),
                         lambda i: (i, 0), (1, ew))),
        fn=lambda s, lab, f: (lab.astype(jnp.int32), f),
        combine=lambda a, b: a + b, name="gda_scatter")

    _, _, make_inputs, reference = gda(n, d, k)
    return Pipeline(name="gda", stages=(feat, scatter)), \
        make_inputs, reference


def kmeans_pipeline(n=256, k=8, d=16):
    """kmeans step in true DAG form: the assign Map (each point's
    nearest centroid, the (n,) fan-out intermediate) feeds BOTH the
    per-cluster scatter-sum and the per-cluster count -- two terminal
    keyed folds sharing one producer.  Fused, the assignment is
    computed once per tile into one VMEM stage buffer read by both
    terminals, and the points tile is DMA'd once per outer step; the
    centroids read is loop-invariant and becomes the Pipe-0 preload."""
    from repro.core.pipeline import Pipeline

    pts = ir.Tensor("points", (n, d))
    cents = ir.Tensor("centroids", (k, d))

    def assign_fn(s, c_all, p_row):
        d2 = jnp.sum((c_all - p_row[None, :]) ** 2, axis=1)
        return jnp.argmin(d2).astype(jnp.float32)

    assign = ir.Map(
        domain=(n,),
        reads=(ir.whole(cents),
               ir.Access(pts, lambda i: (i, 0), (1, d))),
        fn=assign_fn, name="km_assign")

    sums = ir.GroupByFold(
        domain=(n,), num_keys=k, elem_shape=(d,),
        init=lambda: jnp.zeros((k, d)),
        reads=(ir.elem(ir.Tensor("km_assign", (n,))),
               ir.Access(pts, lambda i: (i, 0), (1, d))),
        fn=lambda s, a, p_row: (a.astype(jnp.int32), p_row),
        combine=lambda a, b: a + b, name="km_sums")

    counts = ir.GroupByFold(
        domain=(n,), num_keys=k, elem_shape=(),
        init=lambda: jnp.zeros((k,)),
        reads=(ir.elem(ir.Tensor("km_assign", (n,))),),
        fn=lambda s, a: (a.astype(jnp.int32), jnp.float32(1.0)),
        combine=lambda a, b: a + b, name="km_counts")

    _, _, make_inputs, _ = kmeans(n, k, d)

    def reference(inp):
        pts_ = np.asarray(inp["points"])
        cents_ = np.asarray(inp["centroids"])
        d2 = ((pts_[:, None] - cents_[None]) ** 2).sum(-1)
        idx = d2.argmin(1)
        sums_ = np.zeros((k, d), np.float32)
        counts_ = np.zeros((k,), np.float32)
        for i in range(n):
            sums_[idx[i]] += pts_[i]
            counts_[idx[i]] += 1
        return {"km_sums": sums_, "km_counts": counts_}

    return Pipeline(name="kmeans", stages=(assign, sums, counts)), \
        make_inputs, reference


def gda_moments_pipeline(n=512, d=8, k=4):
    """gda first/second moments as a DAG over one shared feature map:
    a weighted feature Map (the (n, d) fan-out intermediate) feeds BOTH
    the per-class mean accumulator and the per-class second-moment
    (variance numerator) accumulator.  The labels tile is read by both
    terminals but DMA'd once; the weight vector is a Pipe-0 preload."""
    from repro.core.pipeline import Pipeline

    pts = ir.Tensor("pts", (n, d))
    labels = ir.Tensor("labels", (n,))
    weight = ir.Tensor("weight", (d,))

    feat = ir.Map(
        domain=(n,), elem_shape=(d,),
        reads=(ir.Access(pts, lambda i: (i, 0), (1, d)),
               ir.whole(weight)),
        fn=lambda s, row, w: row * w, name="gdam_feat")

    mean = ir.GroupByFold(
        domain=(n,), num_keys=k, elem_shape=(d,),
        init=lambda: jnp.zeros((k, d)),
        reads=(ir.elem(labels),
               ir.Access(ir.Tensor("gdam_feat", (n, d)),
                         lambda i: (i, 0), (1, d))),
        fn=lambda s, lab, f: (lab.astype(jnp.int32), f),
        combine=lambda a, b: a + b, name="gdam_mean")

    var = ir.GroupByFold(
        domain=(n,), num_keys=k, elem_shape=(d,),
        init=lambda: jnp.zeros((k, d)),
        reads=(ir.elem(labels),
               ir.Access(ir.Tensor("gdam_feat", (n, d)),
                         lambda i: (i, 0), (1, d))),
        fn=lambda s, lab, f: (lab.astype(jnp.int32), f * f),
        combine=lambda a, b: a + b, name="gdam_var")

    def make_inputs():
        r = np.random.RandomState(9)
        return {"pts": r.randn(n, d).astype(np.float32),
                "labels": r.randint(0, k, n).astype(np.float32),
                "weight": (r.rand(d) + 0.5).astype(np.float32)}

    def reference(inp):
        f = np.asarray(inp["pts"]) * np.asarray(inp["weight"])[None, :]
        lab = np.asarray(inp["labels"]).astype(np.int32)
        mean_ = np.zeros((k, d), np.float32)
        var_ = np.zeros((k, d), np.float32)
        for i in range(n):
            mean_[lab[i]] += f[i]
            var_[lab[i]] += f[i] * f[i]
        return {"gdam_mean": mean_, "gdam_var": var_}

    return Pipeline(name="gda_moments", stages=(feat, mean, var)), \
        make_inputs, reference


def normalize_pipeline(n=256, d=16):
    """L2 row normalization as map -> map: an inverse-norm Map (the
    (n,) intermediate) feeding a *Map terminal* that rescales each row.
    The terminal lowers through the write-once streaming template (one
    (b, d) output block per grid step, no revisited accumulator); the
    x tile feeds both stages through a single DMA."""
    from repro.core.pipeline import Pipeline

    x = ir.Tensor("x", (n, d))
    eps = 1e-6

    inv = ir.Map(
        domain=(n,),
        reads=(ir.Access(x, lambda i: (i, 0), (1, d)),),
        fn=lambda s, row: 1.0 / jnp.sqrt(jnp.sum(row * row) + eps),
        name="nrm_inv")

    scale = ir.Map(
        domain=(n,), elem_shape=(d,),
        reads=(ir.elem(ir.Tensor("nrm_inv", (n,))),
               ir.Access(x, lambda i: (i, 0), (1, d))),
        fn=lambda s, r, row: row * r, name="nrm_out")

    def make_inputs():
        return {"x": _rng(10, n, d)}

    def reference(inp):
        xs = np.asarray(inp["x"])
        return xs / np.sqrt((xs * xs).sum(1, keepdims=True) + eps)

    return Pipeline(name="normalize", stages=(inv, scale)), \
        make_inputs, reference


PIPELINES = {
    "tpchq6": tpchq6_pipeline,
    "gda": gda_pipeline,
    "kmeans": kmeans_pipeline,
    "gda_moments": gda_moments_pipeline,
    "normalize": normalize_pipeline,
}
