"""Memory allocation analysis (paper §5 "Memory Allocation").

Walks the tiled IR and assigns every memory region to a hardware
structure, mirroring Table 4 of the paper with TPU-idiomatic targets:

  statically-sized array (tile copy)    -> Buffer (VMEM alloc / BlockSpec)
  buffer crossing metapipeline stages   -> Double buffer (Pallas grid
                                           pipelining realizes this)
  non-affine access on a dynamic array  -> Cache  (TPU: gather via
                                           dynamic_slice; no tag memory)
  FlatMap output                        -> Parallel FIFO (TPU: mask +
                                           prefix-sum compaction buffer)
  GroupByFold accumulator               -> CAM (TPU: dense one-hot
                                           accumulator, num_keys bound)

The pass also checks the total against the VMEM budget -- on the FPGA
this is BRAM capacity; exceeding it is a compile-time error in both
worlds.

``plan_memory`` accepts either one tiled pattern or a *sequence* of
patterns that lower into one kernel (the per-terminal trees of a fused
pipeline DAG).  Buffers shared between trees -- a fan-out producer's
stage scratch (same TileCopy uid) or the same external tensor tile
(same ``fusion.tile_copy_key``) -- are allocated and charged exactly
once, with their port count reflecting every reader across the whole
terminal set.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Union

import numpy as np

from . import ir
from .cost import VMEM_BYTES


@dataclasses.dataclass
class BufferAlloc:
    name: str
    kind: str          # buffer | double_buffer | cache | fifo | cam_dense
    words: int
    dtype: str
    double_buffered: bool
    ports: int         # readers + writers (template parameterization)
    depth: int = 1     # buffer copies charged (2 = double buffer)


@dataclasses.dataclass
class MemoryPlan:
    buffers: List[BufferAlloc]
    vmem_budget_bytes: int

    @property
    def total_bytes(self) -> int:
        return sum(b.words * np.dtype(b.dtype).itemsize * max(b.depth, 1)
                   for b in self.buffers)

    @property
    def fits(self) -> bool:
        return self.total_bytes <= self.vmem_budget_bytes

    def describe(self) -> str:
        lines = [f"{'name':24s} {'kind':14s} {'words':>10s} "
                 f"{'depth':>5s} {'ports':>5s}"]
        for b in self.buffers:
            lines.append(f"{b.name:24s} {b.kind:14s} {b.words:>10d} "
                         f"{b.depth:>5d} {b.ports:>5d}")
        lines.append(f"total {self.total_bytes} B / budget "
                     f"{self.vmem_budget_bytes} B -> "
                     f"{'OK' if self.fits else 'OVERFLOW'}")
        return "\n".join(lines)


def plan_memory(p: Union[ir.Pattern, Sequence[ir.Pattern]],
                vmem_budget_bytes: int = VMEM_BYTES,
                depth: int = 2) -> MemoryPlan:
    """VMEM allocation plan for one tiled pattern (or the per-terminal
    trees of a fused pipeline DAG, allocated jointly).

    Parameters
    ----------
    p : tiled pattern, or a sequence of patterns lowering into one
        kernel (buffers shared across trees are charged once).
    vmem_budget_bytes : on-chip capacity the plan is checked against
        (``MemoryPlan.fits``); on the FPGA this is BRAM capacity.
    depth : metapipeline buffer depth charged for every stage-crossing
        buffer (a strided pattern's non-hoisted loads).  Depth 2 is the
        classic double buffer; deeper buffering multiplies the charged
        bytes, so under a fixed budget it competes directly with bigger
        tiles -- the trade ``dse.explore`` searches.  Hoisted preloads,
        caches, FIFOs and CAM accumulators stay single-buffered.
    """
    from . import telemetry
    from .fusion import tile_copy_key  # local import: avoid cycle

    if depth < 2:
        raise ValueError(f"metapipeline depth must be >= 2, got {depth}")

    roots = tuple(p) if isinstance(p, (list, tuple)) else (p,)
    with telemetry.span("memory.plan", roots=len(roots),
                        depth=depth) as sp:
        plan = _plan_memory_body(roots, vmem_budget_bytes, depth,
                                 tile_copy_key)
        sp.set(total_bytes=plan.total_bytes, fits=plan.fits,
               buffers=len(plan.buffers))
    return plan


def _plan_memory_body(roots, vmem_budget_bytes: int, depth: int,
                      tile_copy_key) -> MemoryPlan:
    buffers: List[BufferAlloc] = []
    readers: Dict = {}

    # count readers of each tile copy (port analysis); fan-out readers
    # in other terminal trees accumulate onto the same shared buffer
    for root in roots:
        for q in ir.walk(root):
            for a in q.accesses:
                if isinstance(a.src, ir.TileCopy):
                    k = tile_copy_key(a.src)
                    readers[k] = readers.get(k, 0) + 1

    seen = set()
    idx = [0]

    def visit(q: ir.Pattern):
        for tc in q.loads:
            k = tile_copy_key(tc)
            if k in seen:
                continue
            seen.add(k)
            # a strided pattern's loads are its metapipeline stages:
            # every buffer crossing a stage boundary rotates ``depth``
            # copies (WAR avoidance between overlapped outer
            # iterations; depth 2 = the classic double buffer);
            # hoisted preloads are loop-invariant, so a single copy.
            dbl = q.strided and not tc.hoisted
            kind = "double_buffer" if dbl else "buffer"
            buffers.append(BufferAlloc(
                name=f"{tc.name}#{idx[0]}", kind=kind, words=tc.words,
                dtype=tc.dtype, double_buffered=dbl,
                ports=readers.get(k, 1) + 1,
                depth=depth if dbl else 1))
            idx[0] += 1
            if isinstance(tc.src, ir.Pattern):
                visit(tc.src)
        for a in q.accesses:
            if isinstance(a.src, ir.Tensor) and not a.affine:
                buffers.append(BufferAlloc(
                    name=f"{a.src.name}_cache#{idx[0]}", kind="cache",
                    words=a.words, dtype=a.src.dtype,
                    double_buffered=False, ports=2))
                idx[0] += 1
            elif isinstance(a.src, ir.Pattern):
                visit(a.src)
        if isinstance(q, ir.GroupByFold) and not q.strided:
            buffers.append(BufferAlloc(
                name=f"{q.name}_acc#{idx[0]}", kind="cam_dense",
                words=int(np.prod(q.shape)), dtype=q.dtype,
                double_buffered=False, ports=2))
            idx[0] += 1
        if isinstance(q, ir.FlatMap) and not q.strided:
            buffers.append(BufferAlloc(
                name=f"{q.name}_fifo#{idx[0]}", kind="fifo",
                words=int(np.prod(q.shape)), dtype=q.dtype,
                double_buffered=False, ports=2))
            idx[0] += 1
        if q.inner is not None:
            visit(q.inner)

    for root in roots:
        visit(root)
    return MemoryPlan(buffers, vmem_budget_bytes)
