"""repro.core: the paper's contribution -- PPL IR, tiling, metapipelining."""
from . import affine, codegen_jax, fusion, interchange, ir, rewrite, strip_mine
from .codegen_jax import execute, jit_execute
from .ir import (Access, FlatMap, GroupByFold, Map, MultiFold, Pattern,
                 Tensor, TileCopy, describe, elem, inputs_of, row, signature,
                 walk, whole)
from .strip_mine import insert_tile_copies, strip_mine, tile
from .interchange import interchange, should_split
from .fusion import lift_tile_stages
