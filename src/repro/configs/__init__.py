"""Architecture registry: --arch <id> resolves here."""
from . import (granite3_2b, internvl2_1b, llama4_maverick, mamba2_370m,
               mixtral_8x22b, musicgen_medium, nemotron4_15b, qwen2_72b,
               starcoder2_15b, zamba2_2_7b)
from .shapes import SHAPES, ShapeConfig, skip_reason, sub_quadratic

ARCHS = {
    "starcoder2-15b": starcoder2_15b,
    "nemotron-4-15b": nemotron4_15b,
    "granite-3-2b": granite3_2b,
    "qwen2-72b": qwen2_72b,
    "mamba2-370m": mamba2_370m,
    "musicgen-medium": musicgen_medium,
    "zamba2-2.7b": zamba2_2_7b,
    "llama4-maverick-400b-a17b": llama4_maverick,
    "mixtral-8x22b": mixtral_8x22b,
    "internvl2-1b": internvl2_1b,
}


def get_config(arch: str, smoke: bool = False):
    mod = ARCHS[arch]
    return mod.SMOKE if smoke else mod.CONFIG
