"""Unified tuning-option surface for the DSE stack (``dse.Options``).

``explore`` / ``explore_pipeline`` historically grew a 13-kwarg surface
(budget, alignment, cache, shortlist size, hybrid-measure knobs,
resilience policy, ...) that every kernel's ``auto_tile`` path had to
thread through verbatim, and roughly one ``REPRO_*`` env var per kwarg
was consulted ad hoc at whatever layer happened to need it.  This
module collapses both:

  * ``Options`` -- one frozen dataclass holding every exploration
    option.  Unset fields carry the ``UNSET`` sentinel so layers can be
    merged without "was this explicitly passed?" ambiguity.
  * ``Options.from_env()`` -- the single place the tuning ``REPRO_*``
    env vars are read (see its docstring for the full table).
  * precedence -- explicit kwarg > ``options=Options(...)`` > env >
    built-in default, resolved by ``Options.merged`` + ``resolved()``.

The numeric defaults (``MXU``, ``MAX_POINTS``, ``DEPTHS``, ...) live
here rather than in ``dse`` so this module stays a leaf import;
``dse`` re-exports them for compatibility.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional

from . import resilience
from .cost import VMEM_BYTES

MXU = 128     # MXU systolic array edge / lane count
SUBLANE = 8   # VPU sublane count (fp32 min tile is 8 x 128)

# cap on priced candidates per exploration; axes are thinned (keeping
# their endpoints) until the cross product fits.  Recorded on the
# returned TilePlan as ``thinned=True``.
MAX_POINTS = 4096

# Metapipeline buffer depths enumerated per candidate (2 = the classic
# double buffer, the minimum that overlaps producer and consumer
# stages; deeper rotating buffers hide more DMA issue latency but
# charge ``depth x`` VMEM, so they compete with bigger tiles under the
# budget).  The exposed-latency term saturates (cost.metapipeline_time),
# so the optimum is workload-dependent: big tiles hide the latency at
# depth 2 already, small streaming tiles want 3-4.
DEPTHS = (2, 3, 4)

# hybrid-mode defaults: how many analytically shortlisted candidates
# are actually lowered and timed, and the measurement shape
TOP_K = 3
MEASURE_WARMUP = 1
MEASURE_REPEAT = 3


class _Unset:
    """Singleton sentinel distinguishing "not passed" from ``None`` /
    ``False`` (both of which are meaningful option values)."""

    _instance: Optional["_Unset"] = None

    def __new__(cls) -> "_Unset":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:
        return "UNSET"

    def __bool__(self) -> bool:
        return False

    def __reduce__(self):
        return (_Unset, ())


UNSET = _Unset()

_DEFAULTS: dict = {
    "vmem_budget": VMEM_BYTES,
    "align": MXU,
    "cache": None,          # None -> default on-disk TuningCache
    "max_points": MAX_POINTS,
    "measure": None,        # None -> purely analytic; "top_k" -> hybrid
    "top_k": TOP_K,
    "timing_db": None,      # None -> default on-disk TimingDB
    "profile": None,        # None -> persisted calibration profile
    "warmup": MEASURE_WARMUP,
    "repeat": MEASURE_REPEAT,
    "depths": DEPTHS,
    "policy": None,         # None -> resilience.default_policy()
    "bucketing": False,     # shape-bucketed warm-start mode (buckets.py)
    "trace": False,         # telemetry tracing spans (telemetry.py)
}

_POLICY_VARS = ("REPRO_TIMEOUT_S", "REPRO_RETRIES", "REPRO_BACKOFF_S",
                "REPRO_CERTIFY")

_TRUTHY = ("1", "true", "on", "yes")


@dataclasses.dataclass(frozen=True)
class Options:
    """Every ``explore`` / ``explore_pipeline`` option in one frozen
    value.  Fields default to ``UNSET``; ``resolved()`` fills the
    built-in defaults.  Precedence when combined with legacy kwargs
    (see ``dse._resolve_options``): explicit kwarg beats ``Options``
    beats env beats default.

    Fields mirror the legacy kwargs exactly: ``vmem_budget`` (bytes),
    ``align`` (lane multiple), ``cache`` (None default / False off /
    path / TuningCache), ``max_points``, ``measure`` (None or
    ``"top_k"``), ``top_k``, ``timing_db`` (None / False / path /
    TimingDB), ``profile`` (None persisted / False uncalibrated /
    object), ``warmup``, ``repeat``, ``depths``,
    ``policy`` (resilience.Policy), plus the ``bucketing`` flag
    enabling shape-bucketed warm starts (``core.buckets``) and the
    ``trace`` flag enabling telemetry spans (``core.telemetry``).
    """

    vmem_budget: Any = UNSET
    align: Any = UNSET
    cache: Any = UNSET
    max_points: Any = UNSET
    measure: Any = UNSET
    top_k: Any = UNSET
    timing_db: Any = UNSET
    profile: Any = UNSET
    warmup: Any = UNSET
    repeat: Any = UNSET
    depths: Any = UNSET
    policy: Any = UNSET
    bucketing: Any = UNSET
    trace: Any = UNSET

    @classmethod
    def from_env(cls) -> "Options":
        """The single place the tuning ``REPRO_*`` env vars are read.

        ===================  ============================================
        ``REPRO_MEASURE``    ``measure`` (``top_k`` -> hybrid DSE)
        ``REPRO_DSE_CACHE``  ``cache`` (tuning-cache path)
        ``REPRO_TIMING_DB``  ``timing_db`` (timing-DB path)
        ``REPRO_TIMEOUT_S``  \\
        ``REPRO_RETRIES``     } ``policy`` (built via
        ``REPRO_BACKOFF_S``   } ``resilience.default_policy`` when any
        ``REPRO_CERTIFY``    /  of the four is set)
        ``REPRO_BUCKETING``  ``bucketing`` (1/true/on/yes enables)
        ``REPRO_TRACE``      ``trace`` (1/true/on/yes enables spans)
        ===================  ============================================

        Two further families are consumed downstream of the options
        they configure: ``REPRO_CALIB_PROFILE`` names the on-disk
        calibration *file* that a ``profile=None`` resolution loads
        (``calibrate.load_profile``), and ``REPRO_FAULTS`` /
        ``REPRO_FAULTS_SEED`` drive chaos injection
        (``resilience.inject``), which is deliberately not an
        exploration option.
        """
        kw: dict = {}
        m = os.environ.get("REPRO_MEASURE")
        if m is not None:
            kw["measure"] = m or None
        c = os.environ.get("REPRO_DSE_CACHE")
        if c:
            kw["cache"] = c
        t = os.environ.get("REPRO_TIMING_DB")
        if t:
            kw["timing_db"] = t
        if any(os.environ.get(v) is not None for v in _POLICY_VARS):
            kw["policy"] = resilience.default_policy()
        b = os.environ.get("REPRO_BUCKETING")
        if b is not None:
            kw["bucketing"] = b.strip().lower() in _TRUTHY
        tr = os.environ.get("REPRO_TRACE")
        if tr is not None:
            kw["trace"] = tr.strip().lower() in _TRUTHY
        return cls(**kw)

    @staticmethod
    def merged(*layers: "Options") -> "Options":
        """Per-field first-non-``UNSET`` merge, highest priority first."""
        kw: dict = {}
        for f in dataclasses.fields(Options):
            for layer in layers:
                v = getattr(layer, f.name)
                if v is not UNSET:
                    kw[f.name] = v
                    break
        return Options(**kw)

    def resolved(self) -> "Options":
        """``UNSET`` fields replaced by the built-in defaults, with the
        value-level normalization the legacy kwargs applied:
        ``measure`` in (None, False, "") -> None (else must be
        ``"top_k"``), ``depths`` coerced to a tuple of ints."""
        kw = {f.name: getattr(self, f.name)
              for f in dataclasses.fields(self)}
        for k, v in kw.items():
            if v is UNSET:
                kw[k] = _DEFAULTS[k]
        if kw["measure"] in (None, False, ""):
            kw["measure"] = None
        elif kw["measure"] != "top_k":
            raise ValueError(f"measure={kw['measure']!r}; "
                             f"supported: None, 'top_k'")
        kw["depths"] = tuple(int(d) for d in kw["depths"])
        kw["bucketing"] = bool(kw["bucketing"])
        kw["trace"] = bool(kw["trace"])
        return Options(**kw)
