"""Paged serving decode on the pattern substrate (ISSUE 9).

The load-bearing claim: ``paged_decode_step`` -- page-scattered KV,
per-request ragged lengths, both KV layouts, reference and fused
Pallas paths -- is *token-identical* to the ``model.decode_step``
oracle, across mixed lengths and page-boundary crossings.  Plus the
regression tests for the three seam bugfixes this PR rode in on
(mesh ``AxisType`` guard, ``resolve_plan`` unhashable-key memo,
dry-run ``cost_analysis`` normalization) and the DSE provenance of
the new joint layout x page_size x block axes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ir
from repro.core.pipeline import Pipeline, ragged_extent
from repro.kernels import ops
from repro.models import model, paged

ARCH = "granite-3-2b"
LENS = (3, 5, 9)      # crosses page boundaries at 4 and 8 (ps=4)
PS = 4
GEN = 5


def _greedy(logits, cfg):
    logits = model.mask_vocab_pad(logits, cfg)
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)


def _decode_tokens_oracle(cfg, params, prompt, gen, cmax):
    """Greedy tokens from ``model.decode_step`` with a dense no-wrap
    cache of the page-padded extent (== the paged gather extent, so
    the comparison is bit-exact, not tolerance-based)."""
    cache = model.init_cache(cfg, 1, cmax)
    out, nxt = [], None
    ln = prompt.shape[1]
    for i in range(ln + gen):
        tok = (prompt[:, i:i + 1] if i < ln
               else np.asarray(nxt).reshape(1, 1))
        logits, cache = model.decode_step(params, cfg, cache,
                                          jnp.asarray(tok, jnp.int32),
                                          jnp.int32(i))
        nxt = _greedy(logits, cfg)
        if i >= ln:
            out.append(int(np.asarray(nxt)[0]))
    return out


def _decode_tokens_paged(cfg, params, prompt, gen, cmax, layout,
                         use_pallas):
    cache = paged.PagedKVCache.init(cfg, 1, cmax, page_size=PS,
                                    layout=layout)

    @jax.jit
    def step(params, cache, tok):
        logits, cache = paged.paged_decode_step(params, cfg, cache, tok,
                                                use_pallas=use_pallas)
        return _greedy(logits, cfg), cache

    out, nxt = [], None
    ln = prompt.shape[1]
    for i in range(ln + gen):
        tok = (prompt[:, i:i + 1] if i < ln
               else np.asarray(nxt).reshape(1, 1))
        nxt, cache = step(params, cache, jnp.asarray(tok, jnp.int32))
        if i >= ln:
            out.append(int(np.asarray(nxt)[0]))
    return out


@pytest.mark.parametrize("layout", paged.LAYOUTS)
def test_cache_scatter_gather_roundtrip(layout):
    """``write_tokens`` then ``gather_dense`` is an exact permutation
    round-trip for both KV layouts (including the head-interleaved
    fused packing: K at even head index, V at odd)."""
    cfg = get_config(ARCH, smoke=True)
    cmax = 3 * PS
    cache = paged.PagedKVCache.init(cfg, 2, cmax, page_size=PS,
                                    layout=layout)
    rng = np.random.RandomState(0)
    shp = (cfg.n_layers, cfg.n_kv_heads, 7, cfg.head_dim)
    k = jnp.asarray(rng.randn(*shp), cache.buffers[0].dtype)
    v = jnp.asarray(rng.randn(*shp), cache.buffers[0].dtype)
    cache = cache.assign_pages(1, [3, 5, 1], 7)   # non-linear page map
    cache = cache.write_tokens(1, k, v, 0)
    for li in range(cfg.n_layers):
        ck, cv = cache.gather_dense(li)
        np.testing.assert_array_equal(np.asarray(ck[1, :, :7], np.float32),
                                      np.asarray(k[li], np.float32))
        np.testing.assert_array_equal(np.asarray(cv[1, :, :7], np.float32),
                                      np.asarray(v[li], np.float32))


@pytest.mark.parametrize("layout", paged.LAYOUTS)
def test_paged_decode_token_identical_to_oracle(layout):
    """Reference AND fused-Pallas paged decode match the dense-cache
    oracle token-for-token: mixed prompt lengths, page-boundary
    crossings, both KV layouts."""
    cfg = get_config(ARCH, smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    cmax = -(-(max(LENS) + GEN) // PS) * PS
    rng = np.random.RandomState(1)
    for ln in LENS:
        prompt = rng.randint(0, cfg.vocab, (1, ln))
        want = _decode_tokens_oracle(cfg, params, prompt, GEN, cmax)
        got_ref = _decode_tokens_paged(cfg, params, prompt, GEN, cmax,
                                       layout, use_pallas=False)
        got_pl = _decode_tokens_paged(cfg, params, prompt, GEN, cmax,
                                      layout, use_pallas=True)
        assert got_ref == want, f"reference path diverged at ln={ln}"
        assert got_pl == want, f"pallas path diverged at ln={ln}"


def test_paged_decode_dse_axes_in_provenance():
    """KV layout, page size, streaming block and buffer depth are
    jointly searched axes, recorded in the plan's provenance."""
    (layout, ps, block, depth), plan = ops.resolve_plan(
        "paged_decode", 48, 16)
    assert layout in paged.LAYOUTS
    assert plan.sizes["pd_layout"] == (paged.LAYOUTS.index(layout),)
    assert plan.sizes["pd_page"] == (ps,)
    assert plan.sizes["pd_kv"] == (block,)
    assert plan.depths["pd_kv"] == depth
    assert plan.traffic_words > 0 and plan.modeled_seconds > 0


def test_ragged_extent_on_pipeline_stages():
    """Ragged streaming domains validate (shared extent, granularity
    divides it) and change the stage signature -- so plans for ragged
    and dense variants of the same DAG never collide in the cache."""
    from repro.core import dse

    pipe = dse.paged_decode_pipeline(12, 4, 8, "fused")
    rag = ragged_extent(pipe)
    assert rag is not None and rag.granularity == 4
    assert rag.max == 12 and rag.max_units == 3
    dense_like = [s for s in pipe.stages if s.ragged is None]
    assert not dense_like
    no_rag = ir.Map(domain=pipe.stages[0].domain,
                    elem_shape=pipe.stages[0].elem_shape,
                    reads=pipe.stages[0].reads,
                    fn=pipe.stages[0].fn, name=pipe.stages[0].name)
    assert ir.signature(pipe.stages[0]) != ir.signature(no_rag)

    bad = ir.RaggedExtent(max=12, length_name="seq_len", granularity=5)
    with pytest.raises(ValueError):
        Pipeline(name="bad", stages=(
            ir.Map(domain=(12,), elem_shape=(), reads=no_rag.reads,
                   fn=no_rag.fn, name="m", ragged=bad),)).validate()


def test_resolve_plan_survives_unhashable_memo_key():
    """Regression (ISSUE 9 satellite): an unhashable policy/options
    must skip the in-process memo, not crash the resolve -- and the
    second resolve must return the same plan."""
    b1, _ = ops.resolve_plan("paged_decode", 16, 8,
                             policy={"unhashable": True})
    b2, _ = ops.resolve_plan("paged_decode", 16, 8,
                             policy={"unhashable": True})
    assert b1 == b2


def test_mesh_axis_type_guard():
    """Regression (ISSUE 9 satellite): mesh construction works with
    and without ``jax.sharding.AxisType`` (the jax-version seam that
    broke the dry-run subprocess cell)."""
    from repro.launch import mesh as mesh_mod

    kw = mesh_mod._axis_type_kwargs(2)
    if mesh_mod._AXIS_TYPE is None:
        assert kw == {}
    else:
        assert len(kw["axis_types"]) == 2
    old = mesh_mod._AXIS_TYPE
    try:
        mesh_mod._AXIS_TYPE = None
        assert mesh_mod._axis_type_kwargs(3) == {}
    finally:
        mesh_mod._AXIS_TYPE = old


def test_dryrun_cost_analysis_normalization():
    """Regression (ISSUE 9 satellite): ``cost_analysis()`` results are
    normalized whether jax returns a per-program list (0.4.x) or the
    dict itself (newer)."""
    from repro.launch.dryrun import _cost_analysis_dict

    assert _cost_analysis_dict([{"flops": 1.0}]) == {"flops": 1.0}
    assert _cost_analysis_dict([]) == {}
    assert _cost_analysis_dict({"flops": 2.0}) == {"flops": 2.0}
    assert _cost_analysis_dict(None) == {}


def test_paged_rejects_sliding_window_and_recurrent():
    cfg = get_config(ARCH, smoke=True)
    import dataclasses
    swcfg = dataclasses.replace(cfg, sliding_window=4)
    with pytest.raises(NotImplementedError):
        paged.PagedKVCache.init(swcfg, 1, 8, page_size=4)


def test_decode_traffic_model_prefers_live_pages():
    """The modeled paged decode traffic charges live pages only, so a
    ragged batch undercuts the dense max-context accounting."""
    from repro.core import cost

    dense = cost.dense_decode_traffic_words(3, 64, 2, 16)
    pg = cost.paged_decode_traffic_words([5, 9, 33], 8, 2, 16)
    assert pg < dense
    # page granularity: 9 live tokens pay for 2 pages of 8
    one = cost.paged_decode_traffic_words([9], 8, 2, 16)
    assert one == 2 * 2 * 8 * 2 * 16 + 3 * 2 * 16
