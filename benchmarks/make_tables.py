"""Append the final roofline tables to EXPERIMENTS.md (run after the
dry-run sweeps finish)."""
import io
import sys
from contextlib import redirect_stdout

sys.argv = ["roofline", "results_single.jsonl", "--markdown"]
import benchmarks.roofline as rl  # noqa: E402

out = io.StringIO()
with redirect_stdout(out):
    rl.main()
single = out.getvalue()

sys.argv = ["roofline", "results_multi.jsonl", "--markdown"]
out = io.StringIO()
with redirect_stdout(out):
    rl.main()
multi = out.getvalue()

with open("EXPERIMENTS.md") as f:
    txt = f.read()
marker = "(The final sweep's table is appended below by `make_tables.py`"
head = txt.split(marker)[0]
with open("EXPERIMENTS.md", "w") as f:
    f.write(head)
    f.write("### Single-pod (16x16 = 256 chips), optimized\n\n")
    f.write(single)
    f.write("\n### Multi-pod (2x16x16 = 512 chips), optimized\n\n")
    f.write(multi)
print("tables appended")
