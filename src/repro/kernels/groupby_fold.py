"""GroupByFold kernel: dense keyed reduction via one-hot matmul.

The TPU-idiomatic replacement for the paper's CAM template (Table 4):
instead of an associative key match, keys become a one-hot routing
matrix pushed through the MXU, accumulated into a revisited output
block across the (sequential) grid.  Used by MoE routing (expert counts
and dispatch sums) and the k-means/histogram benchmarks.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _auto_blocks(t: int, num_keys: int, ew: int,
                 measure: Optional[str] = None, policy=None,
                 options=None) -> int:
    from .ops import resolve_plan  # shared memoized selector front door
    bt, _ = resolve_plan("groupby", t, num_keys, ew, measure=measure,
                         policy=policy, options=options)
    return bt


def _gbf_kernel(k_ref, v_ref, o_ref, *, num_keys: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    keys = k_ref[...]                             # (bt,)
    vals = v_ref[...].astype(jnp.float32)         # (bt, ew)
    onehot = jax.nn.one_hot(keys, num_keys, dtype=jnp.float32)
    o_ref[...] += jnp.dot(onehot.T, vals,
                          preferred_element_type=jnp.float32
                          ).astype(o_ref.dtype)


def groupby_fold(keys: jax.Array, values: jax.Array, num_keys: int, *,
                 block_t: int = 256, auto_tile: bool = False,
                 measure: Optional[str] = None, policy=None,
                 options=None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """out[k] = sum over i with keys[i]==k of values[i].

    keys: (T,) int32; values: (T,) or (T, E) -> out (num_keys, E).
    ``auto_tile=True`` picks block_t by DSE on the keyed-fold proxy
    (``repro.core.dse.groupby_program``); ``measure="top_k"`` backs the
    choice with real timings (hybrid DSE); ``policy`` (a
    ``core.resilience.Policy``) bounds the measured exploration."""
    squeeze = values.ndim == 1
    if squeeze:
        values = values[:, None]
    t, ew = values.shape
    if auto_tile:
        block_t = _auto_blocks(t, num_keys, ew, measure, policy, options)
    block_t = min(block_t, t)
    assert t % block_t == 0
    out = pl.pallas_call(
        functools.partial(_gbf_kernel, num_keys=num_keys),
        grid=(t // block_t,),
        in_specs=[
            pl.BlockSpec((block_t,), lambda i: (i,)),
            pl.BlockSpec((block_t, ew), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_keys, ew), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_keys, ew), jnp.float32),
        interpret=INTERPRET if interpret is None else interpret,
    )(keys, values)
    return out[:, 0] if squeeze else out
