"""Fused k-means step megakernel (the paper's Fig. 4/5 DAG, one kernel).

The assign -> {scatter-sum, count} DAG lowered as ONE ``pallas_call``
with TWO outputs: the assign stage computes each point tile's nearest
centroid into a VMEM scratch buffer (the fan-out intermediate -- it
never touches HBM and is computed once per grid step however many
consumers it has), and both terminal accumulators consume that scratch
in place: the per-cluster coordinate sums and the per-cluster counts,
each a revisited CAM-template block.  The points tile is DMA'd once per
grid step and read by both the assign stage and the sum scatter; the
centroids are loop-invariant (the Pipe-0 preload, constant index map).

This is the hand-written shape that ``core.pipeline.lower_pipeline``
generates for ``patterns.analytics.kmeans_pipeline``; keeping it as an
explicit kernel (like ``kernels.fused_filter_fold`` for the chain case)
pins down the multi-output megakernel template in plain Pallas.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INTERPRET = True


def _auto_blocks(n: int, k: int, d: int,
                 measure: Optional[str] = None, policy=None,
                 options=None) -> int:
    from .ops import resolve_plan  # shared memoized selector front door
    bn, _ = resolve_plan("fused_kmeans", n, k, d, measure=measure,
                         policy=policy, options=options)
    return bn


def _km_kernel(pts_ref, cents_ref, sums_ref, counts_ref, assign_ref):
    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    # stage (fan-out intermediate): nearest centroid per point -> VMEM
    pts = pts_ref[...]                       # (b, d)
    cents = cents_ref[...]                   # (k, d) preload
    d2 = ((pts[:, None, :] - cents[None, :, :]) ** 2).sum(-1)  # (b, k)
    assign_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)

    # both terminals consume the SAME scratch (ref-counted fan-out)
    onehot = jax.nn.one_hot(assign_ref[...], cents.shape[0],
                            dtype=sums_ref.dtype)               # (b, k)
    sums_ref[...] += jnp.dot(onehot.T, pts,
                             preferred_element_type=sums_ref.dtype)
    counts_ref[...] += onehot.sum(0)[:, None]


def fused_kmeans_step(points: jax.Array, centroids: jax.Array, *,
                      block_n: int = 128, auto_tile: bool = False,
                      measure: Optional[str] = None, policy=None,
                      options=None,
                      interpret: Optional[bool] = None
                      ) -> Tuple[jax.Array, jax.Array]:
    """One k-means update step as a single two-output megakernel:
    returns ``(sums, counts)`` with ``sums[k] = sum of points assigned
    to centroid k`` and ``counts[k]`` their number.  ``auto_tile=True``
    picks ``block_n`` by joint DSE on the assign -> {sum, count} DAG
    (``core.dse.select_fused_kmeans_blocks`` -- one plan for the whole
    DAG, cached on its topological signature); ``policy`` (a
    ``core.resilience.Policy``) bounds any measured exploration with
    deadlines, quarantine and plan certification."""
    n, d = points.shape
    k, d2 = centroids.shape
    assert d == d2, (points.shape, centroids.shape)
    if auto_tile:
        block_n = _auto_blocks(n, k, d, measure, policy, options)
    block_n = min(block_n, n)
    assert n % block_n == 0
    sums, counts = pl.pallas_call(
        _km_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),   # Pipe-0 preload
        ],
        out_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),   # revisited
            pl.BlockSpec((k, 1), lambda i: (0, 0)),   # revisited
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_n,), jnp.int32)],
        interpret=INTERPRET if interpret is None else interpret,
    )(points, centroids)
    return sums, counts[:, 0]
