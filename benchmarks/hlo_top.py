"""HLO hot-spot inspector: rank ops in a compiled dry-run cell.

    PYTHONPATH=src python -m benchmarks.hlo_top --arch qwen2-72b \
        --shape train_4k --kind all-gather --top 10

Compiles the cell at 1 scan-group (unrolled) so per-layer ops are
visible, then ranks ops of ``--kind`` (a collective, or "fusion" for
memory traffic) by result bytes, printing the JAX source metadata --
this is the "profile" of the dry-run perf loop (EXPERIMENTS.md §Perf).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import re  # noqa: E402
from collections import defaultdict  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--kind", default="all-gather")
    ap.add_argument("--top", type=int, default=12)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--groups", type=int, default=1,
                    help="scan groups to unroll")
    args = ap.parse_args()

    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import _SHAPE_RE, _BYTES, _scan_group
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(args.arch)
    g = _scan_group(cfg)
    cfg = cfg.with_(n_layers=args.groups * g, unroll=True)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)

    # reuse the lowering path but keep the compiled text
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import shard_rules, steps
    from repro.models import model
    from repro.models.sharding import use_mesh_hints
    from repro.optim import adamw

    pspecs = model.param_specs(cfg)
    psh = shard_rules.param_sharding(cfg, mesh, pspecs)
    with mesh, use_mesh_hints(mesh):
        if shape.kind == "train":
            opt_cfg = adamw.AdamWConfig()
            ospecs = adamw.state_specs(pspecs, opt_cfg)
            osh = shard_rules.opt_state_sharding(cfg, mesh, pspecs, ospecs)
            bspecs = steps.input_specs(cfg, shape)
            bsh = shard_rules.batch_sharding(mesh, bspecs)
            fn = steps.make_train_step(cfg, opt_cfg)
            lowered = jax.jit(fn, in_shardings=(psh, osh, bsh),
                              out_shardings=(NamedSharding(mesh, P()),
                                             psh, osh),
                              donate_argnums=(0, 1)).lower(
                                  pspecs, ospecs, bspecs)
        elif shape.kind == "prefill":
            bspecs = steps.input_specs(cfg, shape)
            bsh = shard_rules.batch_sharding(mesh, bspecs)
            lowered = jax.jit(steps.make_prefill_step(cfg),
                              in_shardings=(psh, bsh)).lower(pspecs,
                                                             bspecs)
        else:
            cspecs, ispec = steps.decode_extras(cfg, shape)
            csh = shard_rules.cache_sharding(cfg, mesh, cspecs)
            bspecs = steps.input_specs(cfg, shape)
            bsh = shard_rules.batch_sharding(mesh, bspecs)
            lowered = jax.jit(steps.make_serve_step(cfg),
                              in_shardings=(psh, csh, bsh["tokens"],
                                            NamedSharding(mesh, P())),
                              donate_argnums=(1,)).lower(
                                  pspecs, cspecs, bspecs["tokens"], ispec)
        txt = lowered.compile().as_text()

    meta_re = re.compile(r'op_name="([^"]*)"')
    rows = []
    agg = defaultdict(float)
    for line in txt.splitlines():
        m = re.search(rf"= (.+?) ({re.escape(args.kind)})(-start)?\(",
                      line)
        if not m or "-done(" in line:
            continue
        rbytes = 0
        for dm in _SHAPE_RE.finditer(m.group(1)):
            n = 1
            for d in dm.group(2).split(","):
                if d:
                    n *= int(d)
            rbytes += n * _BYTES[dm.group(1)]
        mm = meta_re.search(line)
        name = mm.group(1) if mm else "?"
        rows.append((rbytes, name))
        agg[name.split("/")[-1][:60]] += rbytes

    rows.sort(reverse=True)
    print(f"top {args.top} {args.kind} ops by result bytes "
          f"(1 layer-group, per device):")
    for rbytes, name in rows[:args.top]:
        print(f"  {rbytes/1e6:10.1f} MB  {name[-110:]}")
    print(f"\n{args.kind} count={len(rows)} "
          f"total={sum(r for r, _ in rows)/1e9:.2f} GB per layer-group")


if __name__ == "__main__":
    main()
