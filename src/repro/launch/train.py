"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --smoke --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires every substrate layer together: config -> model -> sharded data
pipeline -> AdamW (ZeRO sharding on multi-device meshes) -> jitted
train_step -> async checkpointing -> fault-tolerant restart (restores
the latest checkpoint and rewinds the deterministic data stream).
On the CPU container this runs the reduced (--smoke) configs; the same
driver drives the production mesh on real hardware.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.configs import ARCHS, get_config
from repro.data.pipeline import TokenPipeline
from repro.launch import steps as steps_mod
from repro.models import model
from repro.optim import adamw


def train(arch: str, smoke: bool, n_steps: int, batch: int, seq: int,
          ckpt_dir: Optional[str], ckpt_every: int = 10,
          compress_grads: bool = False, log_every: int = 5,
          seed: int = 0):
    cfg = get_config(arch, smoke=smoke)
    opt_cfg = adamw.AdamWConfig(total_steps=n_steps,
                                warmup_steps=max(1, n_steps // 10),
                                compress_grads=compress_grads)
    pipe = TokenPipeline(vocab=cfg.vocab, global_batch=batch, seq_len=seq,
                         seed=seed, n_codebooks=cfg.n_codebooks)

    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = adamw.init(params, opt_cfg)
    start = 0

    writer = None
    if ckpt_dir:
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            print(f"[restore] step {last} from {ckpt_dir}")
            params, opt_state, data_state = ckpt.restore(
                ckpt_dir, last, (params, opt_state, pipe.state_dict()))
            pipe.load_state_dict(jax.tree.map(int, data_state))
            start = last
        writer = ckpt.AsyncCheckpointer(ckpt_dir)

    step_fn = jax.jit(steps_mod.make_train_step(cfg, opt_cfg),
                      donate_argnums=(0, 1))

    losses = []
    t0 = time.time()
    for step in range(start, n_steps):
        batch_np = pipe.next_batch()
        if cfg.family == "vlm":
            batch_np["prefix_embeds"] = np.zeros(
                (batch, cfg.frontend_tokens, cfg.d_model), np.float32)
        loss, params, opt_state = step_fn(params, opt_state, batch_np)
        losses.append(float(loss))
        if (step + 1) % log_every == 0:
            dt = (time.time() - t0) / log_every
            print(f"step {step+1:5d} loss {losses[-1]:.4f} "
                  f"({dt*1e3:.0f} ms/step)")
            t0 = time.time()
        if writer and (step + 1) % ckpt_every == 0:
            writer.save_async(step + 1,
                              (params, opt_state, pipe.state_dict()))
    if writer:
        writer.close()
    return losses, params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()
    losses, _ = train(args.arch, args.smoke, args.steps, args.batch,
                      args.seq, args.ckpt_dir, args.ckpt_every,
                      args.compress_grads)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
