"""End-to-end driver: train a ~130M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

Uses the full production substrate (deterministic pipeline, AdamW,
async checkpointing, crash-restart) on a CPU-feasible ~130M config.
"""
import argparse

from repro.launch.train import train
from repro.models.config import ModelConfig
import repro.configs as configs

# ~130M params: 8 layers x d768 + 32k vocab embeddings
LM_130M = ModelConfig(
    name="lm-130m", family="dense", n_layers=8, d_model=768,
    n_heads=12, n_kv_heads=4, head_dim=64, d_ff=3072, vocab=32000,
    activation="swiglu", remat=False)

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm130m")
    args = ap.parse_args()
    # register the config so the launcher can find it
    class _Mod:  # noqa: N801
        CONFIG = LM_130M
        SMOKE = LM_130M
    configs.ARCHS["lm-130m"] = _Mod
    losses, _ = train("lm-130m", smoke=False, n_steps=args.steps,
                      batch=8, seq=256, ckpt_dir=args.ckpt_dir,
                      ckpt_every=50)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps")
