"""Benchmark harness -- one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:

  fig7/*      the six benchmarks (Table 5) in base / tiled /
              tiled+metapipeline configurations.  us_per_call = CPU
              wall-time of the jnp-lowered program; derived = modeled
              speedup from the analytic cost model (HBM traffic +
              metapipeline overlap -- the quantity Fig. 7 measures on
              the FPGA; see EXPERIMENTS.md §Perf for the comparison).
  fig5c/*     k-means traffic table entries (reads reduction factors).
  table2/*    strip-mining rule structural checks (PASS/FAIL).
  table3/*    gemm interchange + generated-Pallas-kernel equivalence.
  kernels/*   Pallas kernel interpret-mode sanity timings vs oracle.
  roofline/*  per-(arch x shape) dominant-term summary from the latest
              dry-run results, if present.
  autotile/*  (--autotile) per-benchmark comparison of hand-picked vs
              DSE-tuned tile sizes: wall time of the lowered program and
              the cost model's traffic/modeled-seconds accounting, plus
              a depth row (the searched metapipeline buffer depth and
              the depth-2-vs-best modeled delta at the winning sizes).
  fused/*     pipeline fusion (tpchq6 / gda chains, the kmeans and
              gda_moments fan-out DAGs, the normalize Map-terminal
              pipeline): the single-megakernel lowering vs the
              per-pattern DAG -- interpret-mode wall time plus modeled
              HBM traffic (the intermediate round-trips fusion deletes;
              paper Fig. 5/6), and a depth row per pipeline (chosen
              per-group buffer depths + depth-2-vs-best modeled delta).
              These rows feed the CI perf-regression gate
              (``benchmarks/check_regression.py``).
  measured/*  (--measure) hybrid analytic->measured DSE
              (``core.measure`` / ``core.calibrate``): for all five
              Pallas kernels' proxy programs and all five PIPELINES,
              the analytic shortlist's top-k candidates are lowered and
              timed, and the row reports the Spearman rank correlation
              of the analytic and the calibrated model's candidate
              ranking against the measured one, plus the calibration
              profile the samples refreshed.
  serving/*   cold-shape tail latency through the shape-bucket
              warm-start layer (``core.buckets``): per cold shape, the
              first-request latency of a full foreground exploration
              vs the bucketed warm-start resolve, plus the bucket hit
              rate and how many background re-tunes promoted a
              certified winner.  Feeds the serving notes the
              regression gate prints.
  resilience/* degradation accounting for the whole run
              (``core.resilience.LOG``): one row per action taken --
              candidates quarantined, transient retries, analytic
              fallbacks, stores rebuilt from corruption.  Zero rows on
              a clean run; the CI chaos-smoke step injects faults
              (``REPRO_FAULTS``) and asserts these counts are nonzero.

All wall times go through ``core.measure.measure``: warmup runs
(compilation) excluded, median of ``--repeat`` (default 3) fenced
calls.  ``--warmup``/``--repeat`` are recorded in the BENCH json so the
regression gate can flag noisy configurations.

``--only fig5c,table2`` restricts to the named sections (CI smoke).
``--json OUT`` additionally writes the rows as machine-readable
``BENCH_<rev>.json`` (section, name, us, derived, traffic fields) so CI
can archive the perf trajectory per commit; the file is written even
when no rows were produced or a section crashed (empty-but-valid doc).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ir
from repro.core import measure as measure_mod
from repro.core import resilience, telemetry
from repro.core.codegen_jax import execute
from repro.core.cost import traffic
from repro.core.scheduling import build_schedule, model_speedup
from repro.core.strip_mine import insert_tile_copies, strip_mine, tile
from repro.patterns.analytics import PIPELINES, SUITE

ROWS = []
JSON_ROWS = []

# timing configuration (overridden by --repeat/--warmup in main);
# repeat=None means "each call site's historical default", and the
# repeats _time actually used are tracked so the BENCH json reports
# what really happened, not the configured wish
TIMING = {"repeat": None, "warmup": 1, "topk": None,
          "used_min": None, "used_max": None}


def emit(name: str, us: float, derived, **extra) -> None:
    ROWS.append(f"{name},{us:.1f},{derived}")
    JSON_ROWS.append({"section": name.split("/", 1)[0], "name": name,
                      "us": round(float(us), 1), "derived": str(derived),
                      **extra})
    print(ROWS[-1], flush=True)


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__))
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def write_json(out: str, error: str = "") -> str:
    """Write rows as BENCH_<rev>.json; ``out`` is a directory (file named
    by rev) or an explicit ``.json`` path.

    Always emits a valid JSON document -- ``rows`` may be empty (e.g.
    ``--only`` selected a section that produced nothing, or a section
    died before its first row; ``error`` records the latter) so the CI
    artifact upload and the regression gate never face a missing file.
    """
    rev = _git_rev()
    path = out if out.endswith(".json") else os.path.join(
        out, f"BENCH_{rev}.json")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    doc = {"rev": rev, "rows": JSON_ROWS,
           # repeat = the SMALLEST repeat any timed row actually used
           # (sections default to 1-3 when --repeat is unset), so the
           # regression gate's noise note fires on what really ran
           "timing": {"repeat": TIMING["used_min"]
                      or TIMING["repeat"] or 3,
                      "repeat_max": TIMING["used_max"]
                      or TIMING["repeat"] or 3,
                      "warmup": TIMING["warmup"],
                      "device": measure_mod.device_kind(),
                      "interpret": measure_mod.interpret_mode()},
           # degradation accounting for the run: how many candidates
           # were quarantined / retried / fell back (the chaos-smoke CI
           # step asserts these are nonzero under injected faults)
           "resilience": {
               "counts": resilience.LOG.counts(),
               "faults": os.environ.get("REPRO_FAULTS", ""),
               "events": [e.to_json()
                          for e in resilience.LOG.events()[:200]]},
           # unified metrics registry: counters (bucket/cache hits),
           # gauges (model drift / Spearman per family), histograms
           # (serving latency) -- the regression gate prints the
           # model-accuracy gauges next to its verdicts
           "telemetry": telemetry.metrics_snapshot()}
    if error:
        doc["error"] = error
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(f"wrote {len(JSON_ROWS)} rows to {path}")
    if telemetry.enabled():
        tpath = os.path.join(os.path.dirname(path) or ".",
                             f"TRACE_{rev}.json")
        telemetry.export_trace(tpath)
        print(f"wrote trace ({len(telemetry.span_log())} spans) to "
              f"{tpath} -- load in https://ui.perfetto.dev")
    return path


def _time(fn, reps=3):
    """Steady-state µs of ``fn()`` via ``core.measure``: warmup runs
    (compilation) excluded, median of the repeats, every call fenced.
    ``--repeat``/``--warmup`` override every call site's default."""
    repeat = TIMING["repeat"] or reps
    TIMING["used_min"] = min(TIMING["used_min"] or repeat, repeat)
    TIMING["used_max"] = max(TIMING["used_max"] or repeat, repeat)
    m = measure_mod.measure(fn, warmup=TIMING["warmup"], repeat=repeat)
    return m.median_s * 1e6


def _modeled_seconds(prog, metapipelined: bool) -> float:
    """HBM-stream time of the program's main-memory reads; with
    metapipelining, overlapped per the schedule (max of stages)."""
    tr = traffic(prog)
    stream_s = tr.total_reads * 4 / 819e9
    if not metapipelined:
        return stream_s
    mp = build_schedule(prog)
    if mp is None:
        return stream_s
    body_words = sum(s.words for s in mp.stages if s.kind == "body")
    _, _, overlap = model_speedup(mp, flops_per_body=body_words * 100.0)
    return stream_s / max(overlap, 1.0)


def fig7():
    for name, builder in SUITE.items():
        p, sizes, make_inputs, reference = builder()
        inputs = {k: jnp.asarray(v) for k, v in make_inputs().items()}
        ref = np.asarray(reference(inputs))

        tiled_ir = insert_tile_copies(strip_mine(p, sizes))
        full_ir = tile(p, sizes)
        base_s = _modeled_seconds(p, metapipelined=False)
        variants = (("base", p, base_s),
                    ("tiled", tiled_ir,
                     _modeled_seconds(tiled_ir, metapipelined=False)),
                    ("tiled_meta", full_ir,
                     _modeled_seconds(full_ir, metapipelined=True)))
        for label, prog, model_s in variants:
            f = jax.jit(lambda **kw: execute(prog, kw))
            out = f(**inputs)
            if isinstance(out, tuple):
                out = out[0]
            np.testing.assert_allclose(np.asarray(out), ref,
                                       rtol=2e-3, atol=2e-3)
            us = _time(lambda: f(**inputs))
            emit(f"fig7/{name}/{label}", us,
                 f"model_speedup={base_s / max(model_s, 1e-12):.1f}x")


def fig5c():
    from repro.patterns.analytics import kmeans
    n, k, d, b0, b1 = 256, 8, 16, 32, 4
    p, sizes, _, _ = kmeans(n, k, d, b0, b1)
    fused = traffic(p)
    sm = traffic(insert_tile_copies(strip_mine(p, sizes)))
    ic = traffic(tile(p, sizes))
    emit("fig5c/fused/centroids_reads", 0, fused.reads["centroids"])
    emit("fig5c/stripmined/centroids_reads", 0, sm.reads["centroids"])
    emit("fig5c/interchanged/centroids_reads", 0, ic.reads["centroids"])
    ok = ic.reads["centroids"] == (n // b0) * k * d
    factor = fused.reads["centroids"] / ic.reads["centroids"]
    emit("fig5c/interchange_reduction_matches_paper", 0,
         f"{'PASS' if ok else 'FAIL'}(factor={factor:.0f}=b0)")


def table2():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "tests"))
    from test_core_transforms import (mk_filter, mk_hist, mk_map_2x,
                                      mk_sumrows)
    checks = {
        "map": (mk_map_2x(32), {"m": (8,)}, ir.MultiFold),
        "multifold": (mk_sumrows(12, 16), {"sr": (4, 8)}, ir.MultiFold),
        "flatmap": (mk_filter(40), {"f": (8,)}, ir.FlatMap),
        "groupbyfold": (mk_hist(64, 8), {"h": (16,)}, ir.GroupByFold),
    }
    for name, (p, sizes, want) in checks.items():
        t = strip_mine(p, sizes)
        ok = isinstance(t, want) and t.strided and t.inner is not None
        emit(f"table2/{name}", 0, "PASS" if ok else "FAIL")


def table3():
    from repro.core.codegen_pallas import lower, match_tiled_gemm
    p, sizes, make_inputs, reference = SUITE["gemm"]()
    t = tile(p, sizes)
    inputs = make_inputs()
    ok = match_tiled_gemm(t)
    kern = lower(t)
    out = kern(**inputs)
    np.testing.assert_allclose(np.asarray(out), reference(inputs),
                               rtol=2e-3, atol=2e-3)
    us = _time(lambda: kern(**inputs), reps=1)
    emit("table3/gemm_interchanged_kernel", us,
         "PASS" if ok else "FAIL")


def kernels():
    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.matmul import matmul
    from repro.kernels.ssd_scan import ssd_scan

    x = jax.random.normal(jax.random.PRNGKey(0), (256, 256))
    y = jax.random.normal(jax.random.PRNGKey(1), (256, 256))
    us = _time(lambda: matmul(x, y, block_m=128, block_n=128,
                              block_k=128), reps=1)
    err = float(jnp.max(jnp.abs(matmul(x, y) - ref.matmul(x, y))))
    emit("kernels/matmul_256", us, f"max_err={err:.1e}")

    q = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 256, 64))
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.PRNGKey(4), (1, 2, 256, 64))
    us = _time(lambda: flash_attention(q, k, v, block_q=128,
                                       block_k=128), reps=1)
    err = float(jnp.max(jnp.abs(
        flash_attention(q, k, v) - ref.attention(q, k, v))))
    emit("kernels/flash_attention_gqa", us, f"max_err={err:.1e}")

    xs = jax.random.normal(jax.random.PRNGKey(5), (1, 128, 4, 32))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(6),
                                           (1, 128, 4))) * 0.1
    A = -jnp.ones((4,)) * 0.5
    B = jax.random.normal(jax.random.PRNGKey(7), (1, 128, 16))
    C = jax.random.normal(jax.random.PRNGKey(8), (1, 128, 16))
    us = _time(lambda: ssd_scan(xs, dt, A, B, C, chunk=32), reps=1)
    err = float(jnp.max(jnp.abs(ssd_scan(xs, dt, A, B, C, chunk=32)
                                - ref.ssd_scan(xs, dt, A, B, C))))
    emit("kernels/ssd_scan_chunked", us, f"max_err={err:.1e}")


def roofline():
    path = os.path.join(os.path.dirname(__file__), "..",
                        "results_single.jsonl")
    if not os.path.exists(path):
        emit("roofline/skipped", 0, "no results_single.jsonl")
        return
    from benchmarks.roofline import analyze_record
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if "skipped" in r or "error" in r:
                continue
            a = analyze_record(r)
            emit(f"roofline/{r['arch']}/{r['shape']}", 0,
                 f"bottleneck={a['dominant']}"
                 f";frac={a['roofline_fraction']:.3f}")


def _depth_delta_row(section: str, p, plan) -> None:
    """One row per workload: the depth the DSE chose and the modeled
    depth-2-vs-best delta at the winning tile sizes (0% everywhere the
    exposed-DMA-latency term is already saturated at depth 2)."""
    from repro.core.dse import price

    best = price(p, plan.sizes, depth=plan.depth)
    d2 = price(p, plan.sizes, depth=2)
    if best is None or d2 is None:  # depth-2 over budget: report why
        emit(f"{section}/depth", 0,
             f"chosen={plan.depth};depth2=over-vmem", depth=plan.depth)
        return
    delta = (d2.modeled_seconds - best.modeled_seconds) \
        / max(d2.modeled_seconds, 1e-30)
    emit(f"{section}/depth", 0,
         f"chosen={plan.depth};model_d2_vs_best={delta * 100:+.1f}%",
         depth=int(plan.depth), model_d2_vs_best=round(delta, 4))


def autotile():
    """Tuned-vs-hand-picked tile sizes for every suite benchmark: wall
    time of the lowered program plus the cost model's accounting (the
    quantity the DSE argmin optimizes), and the searched metapipeline
    buffer depth with its depth-2-vs-best modeled delta."""
    from repro.core.dse import explore, price

    for name, builder in SUITE.items():
        p, hand_sizes, make_inputs, reference = builder()
        inputs = {k: jnp.asarray(v) for k, v in make_inputs().items()}
        ref = np.asarray(reference(inputs))
        plan = explore(p)
        hand = price(p, hand_sizes)
        variants = (("hand", hand_sizes,
                     hand.traffic_words if hand else "over-vmem"),
                    ("tuned", plan.sizes, plan.traffic_words))
        for label, sizes, words in variants:
            prog = tile(p, sizes)
            f = jax.jit(lambda **kw: execute(prog, kw))
            out = f(**inputs)
            if isinstance(out, tuple):
                out = out[0]
            np.testing.assert_allclose(np.asarray(out), ref,
                                       rtol=2e-3, atol=2e-3)
            us = _time(lambda: f(**inputs))
            emit(f"autotile/{name}/{label}", us,
                 f"traffic_words={words};sizes={dict(sizes)}")
        _depth_delta_row(f"autotile/{name}", p, plan)
        ok = hand is None or plan.traffic_words <= hand.traffic_words
        emit(f"autotile/{name}/tuned_le_hand", 0,
             "PASS" if ok else "FAIL")


def _pipeline_depth_row(section: str, pipe, plan) -> None:
    """Chosen per-group buffer depths + the modeled depth-2-vs-best
    delta, repricing the winning (groups, blocks) with every group
    forced to depth 2 (uncalibrated pricing both ways, so the delta
    isolates the exposed-DMA-latency term deeper buffering buys down).
    """
    from repro.core import dse
    from repro.core import pipeline as plmod
    from repro.core.cost import VMEM_BYTES

    counters = {"explored": 0, "pruned": 0}

    def total_seconds(depths):
        s = 0.0
        for (i0, i1), b, d in zip(plan.groups, plan.group_blocks,
                                  depths):
            pr = dse._price_pipeline_group(
                plmod.sub_pipeline(pipe, i0, i1), b,
                vmem_budget=VMEM_BYTES, profile=None,
                counters=counters, depth=d)
            if pr is None:
                return None
            s += pr[3]
        return s

    chosen = plan.depths or (2,) * len(plan.groups)
    best_s = total_seconds(chosen)
    d2_s = total_seconds((2,) * len(plan.groups))
    if best_s is None or d2_s is None:
        emit(f"{section}/depth", 0,
             f"chosen={list(chosen)};depth2=over-vmem",
             depths=list(map(int, chosen)))
        return
    delta = (d2_s - best_s) / max(d2_s, 1e-30)
    emit(f"{section}/depth", 0,
         f"chosen={list(chosen)};model_d2_vs_best={delta * 100:+.1f}%",
         depths=list(map(int, chosen)),
         model_d2_vs_best=round(delta, 4))


def _check_outputs(pipe, got, ref):
    """Compare a pipeline execution (array or name -> array dict)
    against its reference, output by output."""
    from repro.core.pipeline import output_names

    if not isinstance(ref, dict):
        ref = {output_names(pipe)[0]: np.asarray(ref)}
    if not isinstance(got, dict):
        got = {output_names(pipe)[0]: got}
    for k, want in ref.items():
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)


def fused():
    """Pipeline fusion: fused megakernel vs per-pattern DAG for every
    pipeline in ``PIPELINES`` (chains and fan-out DAGs alike; kmeans
    and gda_moments are multi-output, normalize ends in a write-once
    Map terminal).  Reports interpret-mode wall time and the cost
    model's HBM traffic both ways; the traffic ratio is the fusion win
    the paper's Fig. 5/6 metapipelines bank on, and these rows are the
    perf surface ``benchmarks/check_regression.py`` gates in CI.  Each
    pipeline also reports its searched metapipeline buffer depths and
    the depth-2-vs-best modeled delta at the winning blocks."""
    from repro.core.dse import explore_pipeline
    from repro.core.pipeline import lower_pipeline

    wins = 0
    strict = 0
    for name, builder in PIPELINES.items():
        pipe, make_inputs, reference = builder()
        inputs = {k: jnp.asarray(v) for k, v in make_inputs().items()}
        ref = reference(make_inputs())
        plan = explore_pipeline(pipe)

        fused_f = lower_pipeline(pipe, fused=True, plan=plan)
        unfused_f = lower_pipeline(pipe, fused=False)
        for label, f, words in (
                ("fused", fused_f, plan.traffic_words),
                ("unfused", unfused_f, plan.unfused_traffic_words)):
            _check_outputs(pipe, f(**inputs), ref)
            us = _time(lambda: f(**inputs), reps=1)
            emit(f"fused/{name}/{label}", us,
                 f"traffic_words={words};block={plan.block}",
                 traffic_words=int(words), block=int(plan.block))
        _pipeline_depth_row(f"fused/{name}", pipe, plan)
        ratio = plan.traffic_ratio
        if ratio >= 1.5:
            wins += 1
        if plan.traffic_words < plan.unfused_traffic_words:
            strict += 1
        emit(f"fused/{name}/traffic_ratio", 0, f"{ratio:.2f}x"
             + (";groups=" + str(list(plan.groups)) if not plan.fused
                else ""),
             traffic_ratio=round(ratio, 2))
    emit("fused/ge_1.5x_on_most", 0,
         "PASS" if wins >= len(PIPELINES) - 1 else "FAIL", wins=wins)
    emit("fused/strictly_below_unfused_all", 0,
         "PASS" if strict == len(PIPELINES) else "FAIL", strict=strict)


def _kernel_proxy_programs():
    """The five Pallas kernels' DSE proxy programs at the suite's
    interpret-friendly shapes (one entry per ``auto_tile=True`` kernel)."""
    from repro.core import dse

    return {
        "matmul": dse.gemm_program(256, 256, 256),
        "flash_attention": dse.attention_program(256, 256, 64),
        "ssd_scan": dse.scan_program(256, 16, 32),
        "filter_reduce": dse.filter_reduce_program(4096),
        "groupby_fold": dse.groupby_program(256, 8, 16),
    }


def measured():
    """Hybrid analytic->measured DSE over every kernel proxy and every
    pipeline: lower + time the analytic top-k, fold the samples into
    the device calibration profile, then table the Spearman rank
    correlation of the analytic and the *final* calibrated ranking
    against the measured one.  The gate row checks the calibrated model
    ranks candidates at least as well as the uncalibrated one."""
    from repro.core import calibrate, dse
    from repro.core.cost import HBM_BYTES_PER_S
    from repro.core.measure import spearman

    top_k = TIMING["topk"] or dse.TOP_K
    warmup = TIMING["warmup"]
    repeat = TIMING["repeat"] or dse.MEASURE_REPEAT
    TIMING["used_min"] = min(TIMING["used_min"] or repeat, repeat)
    TIMING["used_max"] = max(TIMING["used_max"] or repeat, repeat)
    # (row name, pattern kind, [(analytic_s, steps, measured_s, label)],
    #  extra json fields)
    tables = []

    for name, p in _kernel_proxy_programs().items():
        # cache=None: the default on-disk tuning cache supplies the
        # persistent candidate quarantine (crashing candidates are
        # skipped on re-runs instead of re-attempted)
        ts = dse.measured_shortlist(p, top_k=top_k, warmup=warmup,
                                    repeat=repeat, cache=None)
        tables.append((f"kernel/{name}", type(p).__name__,
                       [(t.analytic_seconds, t.steps,
                         t.measurement.median_s, str(dict(t.sizes)))
                        for t in ts], {}))
    for name, builder in PIPELINES.items():
        pipe, _, _ = builder()
        ts = dse.measured_pipeline_shortlist(pipe, top_k=top_k,
                                             warmup=warmup, repeat=repeat,
                                             cache=None)
        # measured depth-2-vs-best: the timed (block, depth) variants
        # execute depth-deep rotating scratch, so when both the winner
        # and a depth-2 variant were timed the delta is real, not
        # modeled
        extra = {}
        if ts:
            best_t = min(ts, key=lambda t: t.measurement.median_s)
            d2 = [t for t in ts if t.depth == 2]
            if d2 and best_t.depth != 2:
                d2_s = min(t.measurement.median_s for t in d2)
                extra["measured_d2_vs_best"] = round(
                    (d2_s - best_t.measurement.median_s)
                    / max(d2_s, 1e-30), 4)
        tables.append((f"pipeline/{name}", "Pipeline",
                       [(t.analytic_seconds, t.steps,
                         t.measurement.median_s,
                         f"block={t.block},depth={t.depth}")
                        for t in ts], extra))

    # rank correlations against the FINAL profile (fitted on exactly
    # these samples): its rank guard makes the calibrated mean >= the
    # analytic mean in-sample, the property the gate row asserts
    prof = calibrate.load_profile()
    rhos_a, rhos_c = [], []
    for name, kind, rows, extra in tables:
        if not rows:
            emit(f"measured/{name}", 0, "no-candidates-timed")
            continue
        meas = [r[2] for r in rows]
        ana = [r[0] for r in rows]
        cal = [r[0] if prof is None
               else prof.seconds(kind, r[0] * HBM_BYTES_PER_S, r[1])
               for r in rows]
        rho_a = spearman(ana, meas)
        rho_c = spearman(cal, meas)
        rhos_a.append(rho_a)
        rhos_c.append(rho_c)
        best = min(range(len(rows)), key=lambda i: rows[i][2])
        derived = (f"rho_analytic={rho_a:+.2f};"
                   f"rho_calibrated={rho_c:+.2f};"
                   f"timed={len(rows)};best={rows[best][3]}")
        if "measured_d2_vs_best" in extra:
            derived += (";measured_d2_vs_best="
                        f"{extra['measured_d2_vs_best'] * 100:+.1f}%")
        emit(f"measured/{name}", rows[best][2] * 1e6, derived,
             rho_analytic=round(rho_a, 3), rho_calibrated=round(rho_c, 3),
             timed=len(rows), **extra)

    if prof is not None:
        emit("measured/calibration_profile", 0,
             f"device={prof.device};mode={prof.mode};"
             f"eff_bw={prof.bandwidth_bytes_per_s:.3e}B/s;"
             f"n_samples={prof.n_samples};hash={prof.hash}",
             device=prof.device, mode=prof.mode,
             n_samples=prof.n_samples, profile_hash=prof.hash)
    if not rhos_a:
        # zero timed candidates means zero evidence: a broken
        # lower-for-timing path must not show up as a green gate
        emit("measured/calibrated_ge_analytic", 0,
             "FAIL(no candidates were timed)", timed_workloads=0)
        return
    mean_a = sum(rhos_a) / len(rhos_a)
    mean_c = sum(rhos_c) / len(rhos_c)
    ok = mean_c >= mean_a - 0.05
    emit("measured/calibrated_ge_analytic", 0,
         ("PASS" if ok else "FAIL")
         + f"(mean_rho_calibrated={mean_c:+.2f},"
           f"mean_rho_analytic={mean_a:+.2f})",
         mean_rho_analytic=round(mean_a, 3),
         mean_rho_calibrated=round(mean_c, 3),
         timed_workloads=len(rhos_a))


def serving():
    """Cold-shape tail latency through the shape-bucket warm-start
    layer (``core.buckets``).  One donor shape per kernel family is
    tuned into a scratch cache, then each *cold* shape in the same
    bucket family is explored twice: once cold (fresh cache, full
    foreground exploration -- the first-request latency a bucketless
    server pays) and once bucketed (warm-start plan adapted from the
    donor, background re-tune).  Rows report both latencies, the warm
    plan's provenance, the bucket hit rate, and how many background
    re-tunes promoted a certified winner."""
    import tempfile
    import time as time_mod

    from repro.core import buckets, dse
    from repro.core.options import Options

    tmp = tempfile.mkdtemp(prefix="repro-serving-")
    cache_path = os.path.join(tmp, "dse_cache.json")
    buckets.reset_stats()

    # (family label, program builder, donor shape, cold shapes): cold
    # shapes share the donor's bucket family (same signature/dtype/rank)
    # but were never explored at their exact extents
    cases = [
        ("attention", dse.attention_program,
         (256, 256, 64), [(192, 256, 64), (224, 256, 64)]),
        ("gemm", dse.gemm_program,
         (256, 256, 256), [(250, 250, 250)]),
    ]

    warm_opts = Options(cache=cache_path, bucketing=True)
    for label, build, donor, colds in cases:
        dse.explore(build(*donor), options=warm_opts)  # tune the donor
        for shape in colds:
            p = build(*shape)
            t0 = time_mod.perf_counter()
            dse.explore(p, options=Options(cache=False))
            before_s = time_mod.perf_counter() - t0

            t0 = time_mod.perf_counter()
            plan = dse.explore(p, options=warm_opts)
            after_s = time_mod.perf_counter() - t0
            name = f"serving/{label}/" + "x".join(map(str, shape))
            emit(name, after_s * 1e6,
                 f"cold_explore={before_s * 1e6:.0f}us;"
                 f"warm_start={plan.warm_start};bucket={plan.bucket}",
                 cold_us=round(before_s * 1e6, 1),
                 warm_us=round(after_s * 1e6, 1),
                 warm_start=bool(plan.warm_start),
                 bucket=plan.bucket)

    buckets.drain()
    st = buckets.stats()
    emit("serving/bucket_hit_rate", 0,
         f"{buckets.hit_rate():.2f}"
         f"(exact={st['exact_hits']},warm={st['warm_hits']},"
         f"miss={st['misses']})",
         hit_rate=round(buckets.hit_rate(), 3), **st)
    emit("serving/background_promotions", 0,
         f"{st['promotions']}/{st['retunes']} re-tunes certified "
         "and promoted",
         promotions=st["promotions"], retunes=st["retunes"],
         retune_failures=st["retune_failures"])
    serving_decode()


def serving_decode():
    """Decode ms/token plain (grouped dense caches) vs paged
    (continuous batching over the paged pool, fused Pallas decode),
    plus the *modeled* decode HBM traffic of the same mixed-length
    trace under both cache disciplines and the continuous-batching
    slot occupancy.  Traffic rows are deterministic (analytic model
    over the trace) and gated by check_regression.py; ms/token rows
    are reported for visibility."""
    from repro.launch import serve as serve_mod

    lens = (3, 5, 9, 4, 6)
    gen, slots = 5, 3
    plain_stats = {}
    serve_mod.serve("granite-3-2b", True, len(lens), 8, gen,
                    prompt_lens=lens, stats_out=plain_stats)
    _, cont = serve_mod.serve_continuous("granite-3-2b", True, slots,
                                         gen, prompt_lens=lens)

    emit("serving/decode_ms_per_token/plain",
         plain_stats["ms_per_token"] * 1e3,
         f"{plain_stats['ms_per_token']:.1f}ms/token "
         f"(grouped dense caches)",
         ms_per_token=round(plain_stats["ms_per_token"], 3))
    emit("serving/decode_ms_per_token/paged",
         cont["ms_per_token"] * 1e3,
         f"{cont['ms_per_token']:.1f}ms/token "
         f"(layout={cont['layout']},page={cont['page_size']},"
         f"pallas={cont['use_pallas']},certified={cont['certified']})",
         ms_per_token=round(cont["ms_per_token"], 3),
         layout=cont["layout"], page_size=cont["page_size"],
         use_pallas=cont["use_pallas"], certified=cont["certified"])
    dense_w = cont["modeled_dense_traffic_words"]
    paged_w = cont["modeled_paged_traffic_words"]
    emit("serving/decode_traffic/plain", 0, f"{dense_w} words "
         "(dense lanes at max context)", traffic_words=dense_w)
    emit("serving/decode_traffic/paged", 0,
         f"{paged_w} words ({dense_w / max(paged_w, 1):.2f}x fewer: "
         "live pages only)", traffic_words=paged_w,
         traffic_ratio=round(dense_w / max(paged_w, 1), 3))
    emit("serving/continuous_occupancy", 0,
         f"{cont['occupancy']:.2f} "
         f"({cont['requests']} requests over {cont['slots']} slots, "
         f"{cont['steps']} steps)",
         occupancy=round(cont["occupancy"], 3),
         requests=cont["requests"], slots=cont["slots"],
         steps=cont["steps"])


def resilience_rows() -> None:
    """One row per degradation action the run took (quarantined /
    retried / fallback / rebuilt / skipped), plus a total.  Zero rows
    on a clean run; the chaos-smoke CI step asserts they are NONZERO
    under injected faults -- proving the tuning runtime degraded
    instead of dying."""
    counts = resilience.LOG.counts()
    for action in sorted(counts):
        emit(f"resilience/{action}", 0, counts[action],
             count=counts[action])
    if counts:
        emit("resilience/total", 0, sum(counts.values()),
             count=sum(counts.values()))


SECTIONS = {
    "fig7": fig7,
    "fig5c": fig5c,
    "table2": table2,
    "table3": table3,
    "kernels": kernels,
    "roofline": roofline,
    "autotile": autotile,
    "fused": fused,
    "measured": measured,
    "serving": serving,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--autotile", action="store_true",
                    help="also run the autotile section (DSE-tuned vs "
                         "hand-picked tile sizes)")
    ap.add_argument("--measure", action="store_true",
                    help="also run the measured section (hybrid "
                         "analytic->measured DSE + calibration, rank-"
                         "correlation table)")
    ap.add_argument("--repeat", type=int, default=None, metavar="N",
                    help="timed repeats per row (median reported; "
                         "default: per-section, 1-3)")
    ap.add_argument("--warmup", type=int, default=1, metavar="N",
                    help="warmup (compile) runs excluded from every "
                         "timing (default 1)")
    ap.add_argument("--topk", type=int, default=None, metavar="K",
                    help="candidates lowered+timed per workload in the "
                         "measured section (default core.dse.TOP_K)")
    ap.add_argument("--only", default=None, metavar="SECTIONS",
                    help="comma-separated subset of sections to run: "
                         + ",".join(SECTIONS))
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write rows as BENCH_<rev>.json (OUT = dir or "
                         ".json path)")
    args = ap.parse_args(argv)
    TIMING["repeat"] = args.repeat
    TIMING["warmup"] = args.warmup
    TIMING["topk"] = args.topk

    if args.only:
        names = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = [s for s in names if s not in SECTIONS]
        if unknown:
            ap.error(f"unknown sections {unknown}; choose from "
                     f"{list(SECTIONS)}")
    else:
        names = [s for s in SECTIONS if s not in ("autotile", "measured")]
    if args.autotile and "autotile" not in names:
        names.append("autotile")
    if args.measure and "measured" not in names:
        names.append("measured")

    error = ""
    try:
        for s in names:
            SECTIONS[s]()
    except BaseException as e:
        error = f"{type(e).__name__}: {e}"
        raise
    finally:
        # degradation summary rows come last so every section's
        # quarantine/fallback/retry activity is already accounted
        resilience_rows()
        print(f"\n{len(ROWS)} benchmark rows emitted")
        if args.json:
            # written even on zero rows or a mid-section crash: the CI
            # artifact / regression gate must always find the file
            write_json(args.json, error=error)


if __name__ == "__main__":
    main()
