"""Affine index-map utilities.

The paper's tiling rules are *pattern matching*, not polyhedral: the only
arithmetic fact they need is the (affine) stride of each access with
respect to each loop index.  Because ``Access.index_map`` callables are
declared affine, we recover ``f(i) = base + M @ i`` exactly by probing
with unit indices -- no symbolic algebra, and non-affine accesses simply
opt out (``affine=False``) instead of failing the whole program (the
paper's key advantage over polyhedral tiling).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence, Tuple



@dataclass(frozen=True)
class AffineMap:
    """``f(idxs) = base + mat @ idxs`` with integer entries.

    ``mat[out_dim][in_dim]``; ``n_in`` inputs, ``len(base)`` outputs.
    """

    base: Tuple[int, ...]
    mat: Tuple[Tuple[int, ...], ...]
    arity: int = -1  # explicit n_in (needed when n_out == 0)

    @property
    def n_in(self) -> int:
        if self.arity >= 0:
            return self.arity
        return len(self.mat[0]) if self.mat else 0

    @property
    def n_out(self) -> int:
        return len(self.base)

    def __call__(self, *idxs):
        assert len(idxs) == self.n_in, (len(idxs), self.n_in)
        return tuple(
            b + sum(m * i for m, i in zip(row, idxs))
            for b, row in zip(self.base, self.mat)
        )

    @staticmethod
    def probe(fn: Callable, n_in: int) -> "AffineMap":
        """Recover an AffineMap from an affine callable by unit probing."""
        zero = (0,) * n_in
        base = tuple(int(v) for v in fn(*zero))
        cols = []
        for j in range(n_in):
            unit = tuple(1 if k == j else 0 for k in range(n_in))
            cols.append([int(v) - b for v, b in zip(fn(*unit), base)])
        mat = tuple(tuple(cols[j][d] for j in range(n_in))
                    for d in range(len(base)))
        return AffineMap(base, mat, arity=n_in)

    def depends_on(self, in_dim: int) -> bool:
        return any(row[in_dim] != 0 for row in self.mat)

    def dependent_dims(self) -> Tuple[int, ...]:
        return tuple(j for j in range(self.n_in) if self.depends_on(j))

    def col(self, in_dim: int) -> Tuple[int, ...]:
        return tuple(row[in_dim] for row in self.mat)

    def drop_inputs(self, keep: Sequence[int]) -> "AffineMap":
        """Restrict to a subset of inputs (others assumed zero)."""
        mat = tuple(tuple(row[j] for j in keep) for row in self.mat)
        return AffineMap(self.base, mat, arity=len(keep))

    def with_zero_base(self) -> "AffineMap":
        return AffineMap((0,) * self.n_out, self.mat, arity=self.n_in)

    def scaled_inputs(self, scales: Sequence[int]) -> "AffineMap":
        """f'(i) = f(scales * i) -- used for grid->element index maps."""
        mat = tuple(tuple(m * s for m, s in zip(row, scales))
                    for row in self.mat)
        return AffineMap(self.base, mat, arity=self.n_in)

    def permuted_inputs(self, perm: Sequence[int]) -> "AffineMap":
        """f'(i) = f(i[perm]) (new input j reads old input perm[j])."""
        mat = tuple(tuple(row[p] for p in perm) for row in self.mat)
        return AffineMap(self.base, mat, arity=len(perm))

    def extended(self, n_extra_front: int, n_extra_back: int) -> "AffineMap":
        """Add ignored inputs before/after the existing ones."""
        mat = tuple(
            (0,) * n_extra_front + tuple(row) + (0,) * n_extra_back
            for row in self.mat
        )
        return AffineMap(self.base, mat,
                         arity=n_extra_front + self.n_in + n_extra_back)


def touched_extent(col_strides: Sequence[Tuple[int, ...]],
                   tile_sizes: Sequence[int],
                   window: Sequence[int]) -> Tuple[int, ...]:
    """Extent of the region touched by a tile of iterations.

    For each output dim d: ``sum_j |stride_j[d]| * (b_j - 1) + window[d]``.
    This is the tile-copy shape rule (sliding windows give overlap and are
    marked with a reuse factor by the caller).
    """
    n_out = len(window)
    ext = list(window)
    for col, b in zip(col_strides, tile_sizes):
        for d in range(n_out):
            ext[d] += abs(col[d]) * (b - 1)
    return tuple(ext)
