"""The pattern-generic DSE subsystem (repro.core.dse).

Covers the ISSUE-1 acceptance surface: argmin == exhaustive search,
over-VMEM candidates rejected, tuning-cache round-trip + shape
invalidation, and the GEMM front-end matching-or-beating the hardcoded
block choice under the cost model.
"""
import itertools
import json
import os

import numpy as np
import pytest

from repro.core import dse, ir
from repro.core.cost import VMEM_BYTES, traffic
from repro.core.strip_mine import tile


# ------------------------------------------------------- candidate space
def test_axis_candidates_aligned_divisors():
    assert dse.axis_candidates(512, 128) == [128, 256, 512]
    assert dse.axis_candidates(64, 128) == [64]      # align clamps
    assert dse.axis_candidates(1, 128) == [1]


def test_axis_candidates_admit_ragged_divisors():
    """Non-power-of-two divisors are candidates too (24/48 for a
    96-wide domain), but every candidate stays a multiple of the
    alignment floor -- a non-128-multiple lane tile is not expressible
    on the hardware."""
    assert dse.axis_candidates(96, 8) == [8, 16, 24, 32, 48, 96]
    assert dse.axis_candidates(192, 64) == [64, 192]
    assert dse.axis_candidates(384, 128) == [128, 384]  # 192 misaligns
    assert dse.axis_candidates(768, 128) == [128, 256, 384, 768]
    for extent in (96, 192, 360, 4096):
        for c in dse.axis_candidates(extent, 8):
            assert extent % c == 0          # strip mining requirement
            assert c == extent or c % 8 == 0  # align floor preserved


def test_axis_candidates_dtype_sublane_alignment():
    """bf16 wants 16-row and int8 32-row sublane multiples; candidates
    that misalign are dropped unless they are the whole extent."""
    assert dse.dtype_sublane("float32") == 8
    assert dse.dtype_sublane("bfloat16") == 16
    assert dse.dtype_sublane("int8") == 32
    assert dse.axis_candidates(96, 8, sublane=8) == [8, 16, 24, 32, 48,
                                                     96]
    assert dse.axis_candidates(96, 8, sublane=16) == [16, 32, 48, 96]
    assert dse.axis_candidates(96, 8, sublane=32) == [32, 96]
    # extent below the sublane: the whole extent stays available
    assert dse.axis_candidates(8, 8, sublane=32) == [8]


def test_tile_space_uses_pattern_dtype():
    import jax.numpy as jnp

    def prog(dtype):
        x = ir.Tensor("x", (96, 128), dtype)
        return ir.Map(domain=(96, 128), reads=(ir.elem(x),),
                      fn=lambda s, e: e, name="m", dtype=dtype)

    rows32 = sorted({c[0] for c in dse.tile_space(prog("float32"),
                                                  align=8)["m"]})
    rows16 = sorted({c[0] for c in dse.tile_space(prog("bfloat16"),
                                                  align=8)["m"]})
    assert 8 in rows32 and 24 in rows32
    assert rows16 == [16, 32, 48, 96]


def test_tile_space_covers_all_named_domains():
    p = dse.gemm_program(256, 256, 256)
    space = dse.tile_space(p)
    assert set(space) == {"gemm", "gemm_k"}
    assert (256, 256) in space["gemm"]
    assert (128,) in space["gemm_k"]


# ------------------------------------------------- argmin == brute force
def test_argmin_matches_exhaustive_search():
    """Brute force over the full (sizes x depth) cross product, with
    the same rank key the shortlist uses (shallowest depth wins
    modeled-seconds ties)."""
    p = dse.gemm_program(256, 256, 256)
    plan = dse.explore(p, cache=False)

    space = dse.tile_space(p)
    names = sorted(space)
    best_key, best_sizes, best_depth = None, None, None
    for combo in itertools.product(*(space[n] for n in names)):
        sizes = dict(zip(names, combo))
        for d in dse.DEPTHS:
            priced = dse.price(p, sizes, depth=d)
            if priced is None:
                continue
            key = (priced.traffic_words, priced.modeled_seconds, d,
                   -priced.vmem_bytes)
            if best_key is None or key < best_key:
                best_key, best_sizes, best_depth = key, sizes, d
    assert best_sizes is not None
    assert plan.sizes == {k: tuple(v) for k, v in best_sizes.items()}
    assert plan.traffic_words == best_key[0]
    assert plan.depth == best_depth


# ------------------------------------------------------- VMEM pruning
def test_over_vmem_candidates_rejected():
    budget = 256 * 1024
    plan = dse.explore(dse.gemm_program(2048, 2048, 2048),
                       vmem_budget=budget, cache=False)
    assert plan.vmem_bytes <= budget
    assert plan.pruned > 0  # the big tiles really were rejected


def test_no_fitting_candidate_raises():
    with pytest.raises(ValueError, match="no tile candidate fits"):
        dse.explore(dse.gemm_program(256, 256, 256), vmem_budget=16,
                    cache=False)


def test_priced_plan_respects_memory_plan():
    """plan_memory on the plan's tiled IR (at the plan's chosen buffer
    depth) agrees with the plan."""
    p = dse.gemm_program(512, 512, 512)
    plan = dse.explore(p, cache=False)
    from repro.core.memory import plan_memory
    mem = plan_memory(tile(p, plan.sizes), vmem_budget_bytes=VMEM_BYTES,
                      depth=plan.depth)
    assert mem.fits
    assert mem.total_bytes == plan.vmem_bytes


# ------------------------------------------------------- tuning cache
def test_cache_round_trip(tmp_path):
    path = str(tmp_path / "dse.json")
    p = dse.gemm_program(256, 256, 256)
    plan1 = dse.explore(p, cache=path)
    assert not plan1.cached
    assert os.path.exists(path)
    plan2 = dse.explore(p, cache=path)
    assert plan2.cached
    assert plan2.sizes == plan1.sizes
    assert plan2.traffic_words == plan1.traffic_words


def test_cache_invalidates_on_shape_change(tmp_path):
    path = str(tmp_path / "dse.json")
    dse.explore(dse.gemm_program(256, 256, 256), cache=path)
    plan = dse.explore(dse.gemm_program(512, 256, 256), cache=path)
    assert not plan.cached  # different shape -> different key -> recompute
    with open(path) as f:
        assert len(json.load(f)) == 2


def test_cache_survives_corruption(tmp_path):
    path = str(tmp_path / "dse.json")
    with open(path, "w") as f:
        f.write("{not json")
    plan = dse.explore(dse.gemm_program(256, 256, 256), cache=path)
    assert not plan.cached
    assert plan.sizes  # recomputed despite the corrupt file


def test_cache_keys_on_resolved_space(tmp_path):
    """A caller-restricted space must not be served a cached plan from a
    full exploration (the key covers the resolved candidate space)."""
    path = str(tmp_path / "dse.json")
    p = dse.gemm_program(512, 512, 512)
    dse.explore(p, cache=path)  # full space: argmin is (512, 512, 512)
    restricted = {"gemm": [(128, 128)], "gemm_k": [(128,)]}
    plan = dse.explore(p, space=restricted, cache=path)
    assert plan.sizes == {"gemm": (128, 128), "gemm_k": (128,)}


def test_pattern_key_sensitive_to_access_windows():
    """Programs differing only in read windows must not share a key."""
    import jax.numpy as jnp

    def build(win):
        x = ir.Tensor("x", (64, 64))
        return ir.MultiFold(
            domain=(64,), range_shape=(), init=lambda: jnp.zeros(()),
            reads=(ir.Access(x, lambda i: (i, 0), win),),
            out_index_map=lambda i: (), update_shape=(),
            fn=lambda s, acc, e: acc, combine=lambda a, b: a + b,
            name="f")

    assert dse.pattern_key(build((1, 64))) != dse.pattern_key(build((2, 64)))


def test_thinning_is_recorded():
    p = dse.gemm_program(512, 512, 512)  # 27-point space
    plan = dse.explore(p, cache=False, max_points=8)
    assert plan.thinned
    full = dse.explore(p, cache=False)
    assert not full.thinned


def test_pattern_key_sensitive_to_budget_and_align():
    p = dse.gemm_program(256, 256, 256)
    k1 = dse.pattern_key(p)
    k2 = dse.pattern_key(p, vmem_budget=VMEM_BYTES // 2)
    k3 = dse.pattern_key(p, align=8)
    assert len({k1, k2, k3}) == 3


# --------------------------------------------- GEMM front-end acceptance
def test_gemm_plan_beats_or_matches_hardcoded():
    """DSE-selected GEMM tiles match or beat the previous hardcoded
    (128, 128, 128) choice under the cost model."""
    from repro.patterns.analytics import gemm
    m = n = k = 512
    plan = dse.explore(dse.gemm_program(m, n, k), cache=False)
    p, hand_sizes, _, _ = gemm(m, n, k, 128, 128, 128)
    hand_traffic = traffic(tile(p, hand_sizes)).total_reads
    assert plan.traffic_words <= hand_traffic
    assert plan.vmem_bytes <= VMEM_BYTES


# --------------------------------------------------- proxy programs
@pytest.mark.parametrize("build,names", [
    (lambda: dse.attention_program(256, 256, 64), {"fa_q", "fa_kv"}),
    (lambda: dse.scan_program(256, 16, 32), {"ssd"}),
    (lambda: dse.filter_reduce_program(2048), {"fr"}),
    (lambda: dse.groupby_program(512, 16, 4), {"gbf"}),
])
def test_proxy_programs_explore(build, names):
    plan = dse.explore(build(), cache=False)
    assert set(plan.sizes) == names
    assert plan.vmem_bytes <= VMEM_BYTES
    for name, sizes in plan.sizes.items():
        assert all(s >= 1 for s in sizes)


def test_selectors_divide_shapes():
    (bq, bk), _ = dse.select_attention_blocks(512, 256, 64, cache=False)
    assert 512 % bq == 0 and 256 % bk == 0
    chunk, _ = dse.select_scan_blocks(512, 16, 32, cache=False)
    assert 512 % chunk == 0
    bt, _ = dse.select_filter_reduce_blocks(4096, cache=False)
    assert 4096 % bt == 0
    bt, _ = dse.select_groupby_blocks(512, 16, 4, cache=False)
    assert 512 % bt == 0


# --------------------------------------------------- codegen integration
def test_lower_auto_gemm_end_to_end(tmp_path):
    from repro.core.codegen_pallas import lower_auto
    p = dse.gemm_program(256, 256, 256)
    kern = lower_auto(p, cache=str(tmp_path / "dse.json"))
    assert kern.tile_plan.sizes
    rng = np.random.RandomState(0)
    x = rng.randn(256, 256).astype(np.float32)
    y = rng.randn(256, 256).astype(np.float32)
    np.testing.assert_allclose(np.asarray(kern(x=x, y=y)), x @ y,
                               rtol=2e-3, atol=2e-3)
