"""Tile-level fusion: lift per-element pattern sources to per-tile stages.

The paper assumes aggressive vertical fusion has run *before* tiling
(Fig. 4 is the fused k-means).  After strip mining, a fused body that
computes a per-element intermediate (e.g. the closest-centroid pair for
one point) sits inside the tile loop as a per-element pattern source.
Splitting it out per the paper's heuristic creates a per-*tile* stage --
the `minDistWithInds` stage of Fig. 5b -- which (a) enables pattern
interchange and (b) becomes a metapipeline stage with its own double
buffer.

``lift_tile_stages`` performs that split: for an unstrided pattern Q
(the tile loop) directly inside a strided outer O, any access whose
source is a per-element pattern S is rewritten to read row ``l`` of a
new stage ``S_tile = Map(Q.domain){ S }`` attached to O as a
pattern-valued TileCopy.  The split is applied only when the
intermediate (``Q.domain + S.shape``) fits on-chip (``should_split``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from . import ir
from .affine import AffineMap
from .interchange import should_split


def _lift_in(outer: ir.Pattern, enc: int, budget: int) -> ir.Pattern:
    """outer = strided pattern; examine its direct inner (the tile loop)."""
    q = outer.inner
    if q is None or q.strided:
        return outer
    kq = len(q.domain)
    new_reads = []
    new_stages = []
    memo: Dict[int, ir.TileCopy] = {}
    changed = False
    for a in q.accesses:
        s = a.src
        if not isinstance(s, ir.Pattern):
            new_reads.append(a)
            continue
        inter_shape = tuple(q.domain) + tuple(s.shape)
        if not should_split(int(np.prod(inter_shape)), budget):
            new_reads.append(a)  # paper heuristic: keep fused
            continue
        if id(s) in memo:
            tc = memo[id(s)]
        else:
            # S's callables were written against (enc_outer, q_local, own);
            # inside Map(Q.domain) at outer level the stack is identical.
            stage = ir.Map(domain=tuple(q.domain), elem_shape=tuple(s.shape),
                           inner=s, name=s.name + "_stage", dtype=s.dtype)
            n_out = len(stage.shape)
            tc = ir.TileCopy(
                src=stage,
                index_map=AffineMap((0,) * n_out,
                                    tuple((0,) * enc for _ in range(n_out)),
                                    arity=enc),
                tile_shape=stage.shape, name=s.name + "_stage")
            memo[id(s)] = tc
            new_stages.append(tc)
        # Q's access now reads its local row of the staged tile
        n_out = len(tc.tile_shape)
        stack_len = enc + kq
        mat = []
        for d_out in range(n_out):
            row = [0] * stack_len
            if d_out < kq:  # leading dims index the tile row by q-local idx
                row[enc + d_out] = 1
            mat.append(tuple(row))
        window = (1,) * kq + tuple(s.shape)
        new_reads.append(dataclasses.replace(
            a, src=tc,
            index_map=AffineMap((0,) * n_out, tuple(mat), arity=stack_len),
            window=window))
        changed = True
    if not changed:
        return outer
    q2 = dataclasses.replace(q, reads=tuple(new_reads))
    return dataclasses.replace(
        outer, inner=q2, tile_loads=tuple(outer.loads) + tuple(new_stages))


def lift_tile_stages(p: ir.Pattern, *, enc: int = 0,
                     vmem_budget_words: int = 4 * 1024 * 1024) -> ir.Pattern:
    """Apply the stage-lifting split everywhere it matches (post-order)."""

    def visit(node: ir.Pattern, enc_: int) -> ir.Pattern:
        updates = {}
        if node.inner is not None:
            updates["inner"] = visit(node.inner, enc_ + len(node.domain))
        rr, ch = [], False
        for a in node.accesses:
            if isinstance(a.src, ir.Pattern):
                ns = visit(a.src, enc_ + len(node.domain))
                if ns is not a.src:
                    rr.append(dataclasses.replace(a, src=ns))
                    ch = True
                    continue
            rr.append(a)
        if ch:
            updates["reads"] = tuple(rr)
        if updates:
            node = dataclasses.replace(node, **updates)
        if node.strided:
            node = _lift_in(node, enc_ + len(node.domain), vmem_budget_words)
        return node

    return visit(p, enc)
